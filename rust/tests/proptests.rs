//! Hand-rolled property tests (no proptest crate offline): randomized
//! scenario generation with a deterministic PRNG + fixed seeds, asserting
//! the library's core invariants across hundreds of generated cases.
//!
//! Case counts scale with the `PROPTEST_CASES` env var (CI: small on PRs,
//! large on the scheduled soak run); unset, each test keeps its default.

use vcmpi::fabric::{FabricConfig, Interconnect};
use vcmpi::mpi::matching::{MatchingState, PostedRecv, SenderInfo, Src, Tag, UnexpectedMsg};
use vcmpi::mpi::{run_cluster, ClusterSpec, CommMatch, Info, MpiConfig};
use vcmpi::platform::Backend;
use vcmpi::sim::SimOutcome;
use vcmpi::util::SplitMix64;

/// Seed count for one property: `PROPTEST_CASES` if set, else `default`.
fn cases(default: u64) -> u64 {
    std::env::var("PROPTEST_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

// ---------------------------------------------------------------------
// Matching-engine invariants (pure data structure: thousands of cases)
// ---------------------------------------------------------------------

fn umsg(comm: u64, src: usize, tag: i32, seq: u64) -> UnexpectedMsg {
    UnexpectedMsg {
        comm_id: comm,
        src_rank: src,
        tag,
        seq,
        sender: SenderInfo { src_proc: src, src_ctx: 0, send_handle: seq },
        arrival: vcmpi::mpi::matching::Arrival::Eager { data: vec![], needs_ack: false },
    }
}

/// Invariant: every arrival is matched at most once, matches always agree
/// on (comm, src-pattern, tag-pattern), and per-stream consumption is FIFO.
#[test]
fn prop_matching_agrees_and_preserves_fifo() {
    for seed in 0..cases(60) {
        let mut rng = SplitMix64::new(seed);
        let mut m = MatchingState::new();
        let mut next_seq = std::collections::HashMap::<(u64, usize), u64>::new();
        let mut last_matched_seq = std::collections::HashMap::<(u64, usize, i32), u64>::new();
        for step in 0..400 {
            if rng.gen_bool(0.5) {
                // Arrival with a random envelope.
                let comm = rng.gen_range(3);
                let src = rng.gen_usize(3);
                let tag = rng.gen_range(3) as i32;
                let seq = {
                    let e = next_seq.entry((comm, src)).or_insert(1);
                    let s = *e;
                    *e += 1;
                    s
                };
                if let Some((p, got)) = m.on_arrival(umsg(comm, src, tag, seq)) {
                    assert_eq!(got.comm_id, comm);
                    assert!(matches!(p.src, Src::Any) || p.src == Src::Rank(src));
                    assert!(matches!(p.tag, Tag::Any) || p.tag == Tag::Value(tag));
                }
            } else {
                // Post with random wildcards.
                let comm = rng.gen_range(3);
                let src =
                    if rng.gen_bool(0.3) { Src::Any } else { Src::Rank(rng.gen_usize(3)) };
                let tag =
                    if rng.gen_bool(0.3) { Tag::Any } else { Tag::Value(rng.gen_range(3) as i32) };
                let posted = PostedRecv { comm_id: comm, src, tag, req: step };
                if let Some(got) = m.on_post(posted) {
                    assert_eq!(got.comm_id, comm);
                    assert!(matches!(src, Src::Any) || src == Src::Rank(got.src_rank));
                    assert!(matches!(tag, Tag::Any) || tag == Tag::Value(got.tag));
                    // FIFO per exact (comm, src, tag) stream.
                    let key = (got.comm_id, got.src_rank, got.tag);
                    let last = last_matched_seq.entry(key).or_insert(0);
                    assert!(
                        got.seq > *last,
                        "seed {seed}: stream {key:?} regressed {} -> {}",
                        last,
                        got.seq
                    );
                    *last = got.seq;
                }
            }
        }
    }
}

/// Reorder-stage invariant vs a single-VCI oracle: feed each stream's
/// seqs in a random interleave (as striped per-VCI delivery would), with
/// random duplicate injections, then drain via posted receives. Every
/// stream must come back exactly once per seq, in seq order — exactly
/// what a single VCI would have delivered — and every duplicate must be
/// counted and dropped.
#[test]
fn prop_striped_reorder_matches_single_vci_oracle() {
    for seed in 0..cases(40) {
        let mut rng = SplitMix64::new(0x57A1 ^ seed);
        let streams = 3usize; // (comm 1, srcs 0..3)
        let per_stream = 1 + rng.gen_usize(30);
        // The "wire": every (src, seq) pair once, plus some duplicates.
        let mut wire: Vec<(usize, u64)> = Vec::new();
        for src in 0..streams {
            for seq in 1..=per_stream as u64 {
                wire.push((src, seq));
            }
        }
        let mut dups = 0u64;
        for _ in 0..rng.gen_usize(10) {
            let src = rng.gen_usize(streams);
            let seq = 1 + rng.gen_usize(per_stream) as u64;
            wire.push((src, seq));
            dups += 1;
        }
        rng.shuffle(&mut wire);

        let mut m = MatchingState::new();
        let mut matched: Vec<Vec<u64>> = vec![Vec::new(); streams];
        // Pre-post some receives so admission exercises both the
        // match-on-arrival and the park-in-unexpected paths.
        for src in 0..streams {
            for _ in 0..rng.gen_usize(per_stream + 1) {
                let posted =
                    PostedRecv { comm_id: 1, src: Src::Rank(src), tag: Tag::Value(7), req: 0 };
                assert!(m.on_post(posted).is_none(), "queue starts empty");
            }
        }
        for &(src, seq) in &wire {
            for (_p, um) in m.on_striped_arrival(umsg(1, src, 7, seq)) {
                matched[um.src_rank].push(um.seq);
            }
        }
        // Drain what parked admission left in the unexpected queue.
        for src in 0..streams {
            while let Some(um) = m.on_post(PostedRecv {
                comm_id: 1,
                src: Src::Rank(src),
                tag: Tag::Value(7),
                req: 0,
            }) {
                matched[um.src_rank].push(um.seq);
            }
        }
        for (src, seqs) in matched.iter().enumerate() {
            let want: Vec<u64> = (1..=per_stream as u64).collect();
            assert_eq!(
                seqs, &want,
                "seed {seed}: stream {src} diverged from the single-VCI oracle"
            );
        }
        assert_eq!(m.dup_seq_drops(), dups, "seed {seed}: duplicate accounting");
        assert_eq!(m.reorder_parked(), 0, "seed {seed}: leftover parked arrivals");
    }
}

/// The sharded engine (`CommMatch`) vs the single-engine oracle, with
/// wildcard epochs in play: a random interleave of striped arrivals
/// (shuffled per-stream seqs + duplicate injections), concrete posts, and
/// `MPI_ANY_SOURCE` posts is mirrored into a plain `MatchingState`. The
/// recv-to-message binding may legally differ (a wildcard may pick a
/// different source), but per-stream delivery must be exactly seq order,
/// every message delivered exactly once, every duplicate dropped and
/// counted, and every opened epoch resolved once its wildcards complete.
#[test]
fn prop_sharded_matching_matches_single_engine_oracle() {
    for seed in 0..cases(30) {
        let mut rng = SplitMix64::new(0x5AAD ^ seed.wrapping_mul(0x9E37));
        let shard_choices = [1usize, 2, 4, 8];
        let shards = shard_choices[rng.gen_usize(shard_choices.len())];
        let linger = rng.gen_range(3) as u32;
        let m = CommMatch::new(Backend::Native, 1, shards, linger);
        let mut oracle = MatchingState::new();
        let srcs = 4usize;
        let per_stream = 1 + rng.gen_usize(16);

        let mut wire: Vec<(usize, u64)> = Vec::new();
        for src in 0..srcs {
            for seq in 1..=per_stream as u64 {
                wire.push((src, seq));
            }
        }
        let mut dups = 0u64;
        for _ in 0..rng.gen_usize(8) {
            let src = rng.gen_usize(srcs);
            let seq = 1 + rng.gen_usize(per_stream) as u64;
            wire.push((src, seq));
            dups += 1;
        }
        rng.shuffle(&mut wire);

        let mut sharded_order: Vec<Vec<u64>> = vec![Vec::new(); srcs];
        let mut oracle_order: Vec<Vec<u64>> = vec![Vec::new(); srcs];
        let mut wildcards_posted = 0u64;
        let mut wildcards_matched_sharded = 0u64;

        fn feed_arrival(
            m: &CommMatch,
            oracle: &mut MatchingState,
            src: usize,
            seq: u64,
            sharded_order: &mut [Vec<u64>],
            oracle_order: &mut [Vec<u64>],
            wildcards_matched_sharded: &mut u64,
        ) {
            let pairs = m.striped_arrival(umsg(1, src, 7, seq)).expect("engine never retired");
            let wilds = pairs.iter().filter(|(p, _)| p.src == Src::Any).count() as u64;
            for (_p, um) in &pairs {
                sharded_order[um.src_rank].push(um.seq);
            }
            m.note_arrival(wilds);
            *wildcards_matched_sharded += wilds;
            for (_p, um) in oracle.on_striped_arrival(umsg(1, src, 7, seq)) {
                oracle_order[um.src_rank].push(um.seq);
            }
        }

        let mut wi = 0usize;
        for _step in 0..(wire.len() + 30) {
            if wi < wire.len() && rng.gen_bool(0.6) {
                let (src, seq) = wire[wi];
                wi += 1;
                feed_arrival(
                    &m,
                    &mut oracle,
                    src,
                    seq,
                    &mut sharded_order,
                    &mut oracle_order,
                    &mut wildcards_matched_sharded,
                );
            } else {
                let src = if rng.gen_bool(0.25) {
                    wildcards_posted += 1;
                    Src::Any
                } else {
                    Src::Rank(rng.gen_usize(srcs))
                };
                let recv = PostedRecv { comm_id: 1, src, tag: Tag::Value(7), req: 0 };
                if let Some(um) = m.post(recv.clone()).expect("engine never retired") {
                    sharded_order[um.src_rank].push(um.seq);
                    if src == Src::Any {
                        wildcards_matched_sharded += 1;
                    }
                }
                if let Some(um) = oracle.on_post(recv) {
                    oracle_order[um.src_rank].push(um.seq);
                }
            }
        }
        // Feed whatever the random phase left on the wire, then drain the
        // unexpected queues with concrete receives.
        while wi < wire.len() {
            let (src, seq) = wire[wi];
            wi += 1;
            feed_arrival(
                &m,
                &mut oracle,
                src,
                seq,
                &mut sharded_order,
                &mut oracle_order,
                &mut wildcards_matched_sharded,
            );
        }
        for src in 0..srcs {
            let recv =
                || PostedRecv { comm_id: 1, src: Src::Rank(src), tag: Tag::Value(7), req: 0 };
            while let Some(um) = m.post(recv()).expect("engine never retired") {
                sharded_order[um.src_rank].push(um.seq);
            }
            while let Some(um) = oracle.on_post(recv()) {
                oracle_order[um.src_rank].push(um.seq);
            }
        }

        let want: Vec<u64> = (1..=per_stream as u64).collect();
        for src in 0..srcs {
            assert_eq!(
                sharded_order[src], want,
                "seed {seed} ({shards} shards, linger {linger}): \
                 stream {src} diverged in the sharded engine"
            );
            assert_eq!(
                oracle_order[src], want,
                "seed {seed}: stream {src} diverged in the oracle"
            );
        }
        let (sharded_dups, sharded_parked) = m.reorder_stats();
        assert_eq!(sharded_dups, dups, "seed {seed}: sharded duplicate accounting");
        assert_eq!(oracle.dup_seq_drops(), dups, "seed {seed}: oracle duplicate accounting");
        assert_eq!(sharded_parked, 0, "seed {seed}: leftover parked arrivals");
        assert_eq!(oracle.reorder_parked(), 0);
        let es = m.epoch_stats();
        assert_eq!(es.wildcard_posts, wildcards_posted, "seed {seed}");
        if shards == 1 {
            assert_eq!(es.flips, 0, "seed {seed}: single shard never epochs");
        } else if wildcards_matched_sharded == wildcards_posted {
            // All wildcards completed: every opened epoch must have closed
            // (hysteresis counts arrivals, and the final drain feeds none,
            // so only a linger-free run is guaranteed to close here).
            if linger == 0 {
                assert_eq!(es.flips, es.unflips, "seed {seed}: unresolved epoch");
            }
            assert!(es.unflips <= es.flips, "seed {seed}");
        } else {
            assert!(m.is_serialized(), "seed {seed}: pending wildcard must hold the epoch");
        }
    }
}

// ---------------------------------------------------------------------
// End-to-end randomized traffic: all payloads delivered exactly once,
// in FIFO order per stream, under every library configuration.
// ---------------------------------------------------------------------

fn random_traffic_case(seed: u64, cfg: MpiConfig, ic: Interconnect) {
    random_traffic_case_sized(seed, cfg, ic, 2000)
}

/// `max_size` selects the protocol mix: 2000 stays within immediate+eager;
/// ~40k spans immediate, eager, and rendezvous.
fn random_traffic_case_sized(seed: u64, cfg: MpiConfig, ic: Interconnect, max_size: usize) {
    let nprocs = 3;
    let spec = ClusterSpec::new(
        FabricConfig { interconnect: ic, nodes: nprocs, procs_per_node: 1, max_contexts_per_node: 64 },
        cfg,
        1,
    );
    let r = run_cluster(spec, move |proc, _t| {
        let world = proc.comm_world();
        let me = proc.rank();
        let n = proc.nprocs();
        let mut rng = SplitMix64::new(seed ^ (me as u64) << 8);
        // Deterministic plan shared by all: who sends how many to whom.
        // plan[i][j] = messages from i to j (derived from the seed only).
        let mut plan = vec![vec![0usize; n]; n];
        let mut prng = SplitMix64::new(seed);
        for row in plan.iter_mut() {
            for cell in row.iter_mut() {
                *cell = prng.gen_usize(14);
            }
        }
        // Sends: to each peer, a numbered stream (payload = index).
        let mut sreqs = Vec::new();
        for dst in 0..n {
            if dst == me {
                continue;
            }
            for k in 0..plan[me][dst] {
                let size = 1 + rng.gen_usize(max_size);
                let mut data = vec![0u8; size];
                data[0] = k as u8;
                sreqs.push(proc.isend(&world, dst, 5, &data));
            }
        }
        // Receives: expect plan[src][me] messages from each src, in order.
        for src in 0..n {
            if src == me {
                continue;
            }
            for k in 0..plan[src][me] {
                let got = proc.recv(
                    &world,
                    vcmpi::mpi::Src::Rank(src),
                    vcmpi::mpi::Tag::Value(5),
                );
                assert_eq!(got[0], k as u8, "stream {src}->{me} out of order");
            }
        }
        proc.waitall(sreqs);
        proc.barrier(&world);
    });
    assert_eq!(r.outcome, SimOutcome::Completed, "seed {seed}");
}

#[test]
fn prop_random_traffic_delivered_in_order_optimized() {
    for seed in 0..cases(12) {
        random_traffic_case(seed, MpiConfig::optimized(6), Interconnect::Opa);
    }
}

#[test]
fn prop_random_traffic_delivered_in_order_original() {
    for seed in 0..cases(6) {
        random_traffic_case(seed, MpiConfig::original(), Interconnect::Ib);
    }
}

#[test]
fn prop_random_traffic_all_policies() {
    use vcmpi::mpi::VciPolicy;
    for policy in [VciPolicy::FirstComePool, VciPolicy::RoundRobin, VciPolicy::Hashed] {
        let mut cfg = MpiConfig::optimized(4);
        cfg.vci_policy = policy;
        random_traffic_case(99, cfg, Interconnect::Opa);
    }
}

/// Striped interleavings of eager + rendezvous sends against the
/// single-VCI oracle: the in-order check inside `random_traffic_case` IS
/// the oracle (a single VCI delivers per-stream FIFO by construction;
/// striping must be observationally identical).
#[test]
fn prop_random_traffic_striped_eager_and_rendezvous() {
    use vcmpi::mpi::VciStriping;
    for seed in 0..cases(8) {
        random_traffic_case_sized(seed, MpiConfig::striped(6), Interconnect::Opa, 40_000);
    }
    let mut hashed = MpiConfig::striped(5);
    hashed.vci_striping = VciStriping::HashedByRequest;
    for seed in 0..cases(4) {
        random_traffic_case_sized(seed, hashed.clone(), Interconnect::Ib, 40_000);
    }
    // Per-source sharded matching: 3 procs -> every receiver matches two
    // striped source streams through distinct shards.
    for seed in 0..cases(6) {
        random_traffic_case_sized(seed, MpiConfig::striped_sharded(6), Interconnect::Opa, 40_000);
    }
}

/// Serial execution streams vs the ordered locked oracle: the same
/// random p2p program (random sizes spanning immediate/eager/rendezvous,
/// random send/recv interleave decided by a shared seed) runs once on a
/// `vcmpi_stream=local` comm — whose owner-side ops take the lock-free
/// single-writer fast path — and once on a plain ordered comm through the
/// locked path. Payload contents must round-trip intact and the delivery
/// order observed on the streamed comm must be exactly the locked comm's
/// (both FIFO per stream: the lock elision must be observationally
/// invisible).
#[test]
fn prop_streamed_vs_locked_comm() {
    fn drive(proc: &std::sync::Arc<vcmpi::mpi::MpiProc>, comm: &vcmpi::mpi::Comm, seed: u64) -> Vec<u32> {
        let me = proc.rank();
        let peer = 1 - me;
        // Same seed on both ranks and both comms: identical program shape.
        let mut prng = SplitMix64::new(seed.wrapping_mul(0x6C07) ^ 0x57E4);
        let nmsgs = 4 + prng.gen_usize(10);
        let mut order = Vec::new();
        let mut received = 0usize;
        let recv_one = |proc: &vcmpi::mpi::MpiProc| {
            let got = proc.recv(comm, Src::Rank(peer), Tag::Value(5));
            let k = u32::from_le_bytes(got[0..4].try_into().unwrap());
            assert!(
                got[4..].iter().all(|&b| b == k as u8),
                "seed {seed}: payload {k} corrupted on comm {}",
                comm.id
            );
            k
        };
        let mut sreqs = Vec::new();
        for k in 0..nmsgs as u32 {
            // Sizes span immediate, eager, and rendezvous.
            let size = 4 + prng.gen_usize(40_000);
            let mut data = vec![k as u8; size];
            data[0..4].copy_from_slice(&k.to_le_bytes());
            sreqs.push(proc.isend(comm, peer, 5, &data));
            if prng.gen_bool(0.5) && received < nmsgs {
                order.push(recv_one(proc));
                received += 1;
            }
        }
        while received < nmsgs {
            order.push(recv_one(proc));
            received += 1;
        }
        proc.waitall(sreqs);
        order
    }
    for seed in 0..cases(6) {
        let spec = ClusterSpec::new(
            FabricConfig {
                interconnect: Interconnect::Ib,
                nodes: 2,
                procs_per_node: 1,
                max_contexts_per_node: 64,
            },
            MpiConfig::optimized(6),
            1,
        );
        let r = run_cluster(spec, move |proc, _t| {
            let world = proc.comm_world();
            let streamed =
                proc.comm_dup_with_info(&world, &Info::new().with("vcmpi_stream", "local"));
            let locked = proc.comm_dup(&world);
            let via_stream = drive(proc, &streamed, seed);
            let via_lock = drive(proc, &locked, seed);
            assert_eq!(
                via_stream, via_lock,
                "seed {seed}: streamed delivery order diverged from the locked oracle"
            );
            let fifo: Vec<u32> = (0..via_lock.len() as u32).collect();
            assert_eq!(via_lock, fifo, "seed {seed}: locked oracle itself must be FIFO");
            // Owner-side teardown: unbind + drain before finalize's
            // no-stream-owned-lanes / no-parked-freelist tripwires.
            proc.comm_free(streamed);
            proc.comm_free(locked);
            proc.barrier(&world);
        });
        assert_eq!(r.outcome, SimOutcome::Completed, "seed {seed}");
    }
}

// ---------------------------------------------------------------------
// Collectives: the segmented/pipelined engine vs a host-computed
// reduction oracle, across every `vcmpi_collectives` policy (rides the
// nightly PROPTEST_CASES=400 soak).
// ---------------------------------------------------------------------

/// Random payload sizes, segment counts, comm sizes, and collectives
/// policies (inherit on ordered and striped comms, dedicated, striped):
/// allreduce must match the host-computed per-element sum, the scalar
/// path must be exact, and bcast from a random root must deliver — then
/// `comm_free` tears the policy (and any dedicated lane) down cleanly.
#[test]
fn prop_collectives_vs_scalar_oracle() {
    for seed in 0..cases(10) {
        let mut rng = SplitMix64::new(0xC011 ^ (seed << 4));
        let nprocs = 2 + rng.gen_usize(4); // 2..=5
        let len = 1 + rng.gen_usize(700);
        let segments = 1 + rng.gen_usize(9); // 1..=9
        let (arm, cfg) = match rng.gen_usize(4) {
            0 => (None, MpiConfig::optimized(5)),
            1 => (None, MpiConfig::striped_sharded(5)),
            2 => (Some("dedicated"), MpiConfig::optimized(5)),
            _ => (Some("striped"), MpiConfig::optimized(5)),
        };
        let root = rng.gen_usize(nprocs);
        let spec = ClusterSpec::new(
            FabricConfig {
                interconnect: Interconnect::Ib,
                nodes: nprocs,
                procs_per_node: 1,
                max_contexts_per_node: 64,
            },
            cfg,
            1,
        );
        let r = run_cluster(spec, move |proc, _t| {
            let world = proc.comm_world();
            let mut info = Info::new().with("vcmpi_coll_segments", segments.to_string());
            if let Some(mode) = arm {
                info.set("vcmpi_collectives", mode);
            }
            let comm = proc.comm_dup_with_info(&world, &info);
            let n = proc.nprocs();
            let mut data: Vec<f32> =
                (0..len).map(|i| ((proc.rank() * 1000 + i) % 97) as f32).collect();
            proc.allreduce_f32(&comm, &mut data);
            for (i, &v) in data.iter().enumerate() {
                let want: f32 = (0..n).map(|r| ((r * 1000 + i) % 97) as f32).sum();
                assert!(
                    (v - want).abs() <= want.abs() * 1e-5 + 1e-3,
                    "seed {seed} i={i}: got {v}, want {want}"
                );
            }
            // Scalar metrics ride the same segmented ring, exactly.
            let s = proc.allreduce_scalar(&comm, (proc.rank() + 1) as f64);
            let want_s: f64 = (1..=n).map(|r| r as f64).sum();
            assert!((s - want_s).abs() < 1e-12, "seed {seed}: scalar {s} want {want_s}");
            // Bcast from a random root through the same policy.
            let payload: Vec<u8> = (0..(len % 211) + 1).map(|i| (i * 7 + root) as u8).collect();
            let got = proc.bcast(
                &comm,
                root,
                if proc.rank() == root { Some(payload.clone()) } else { None },
            );
            assert_eq!(got, payload, "seed {seed}: bcast mismatch");
            proc.comm_free(comm);
            proc.barrier(&world);
        });
        assert_eq!(r.outcome, SimOutcome::Completed, "seed {seed}");
    }
}

/// Nonblocking collectives vs the blocking path + host oracle: random
/// payload sizes, segment counts (including `auto`), comm sizes, and all
/// three lane policies (inherit, dedicated, striped). The blocking forms
/// are initiate+wait over the same resumable schedule, so iallreduce
/// must match blocking allreduce **bit-identically** (same schedule,
/// same reduction order) — and both must match the host-computed sum;
/// ibcast must deliver the root payload with compute between issue and
/// wait on every rank.
#[test]
fn prop_iallreduce_ibcast_vs_blocking() {
    for seed in 0..cases(10) {
        let mut rng = SplitMix64::new(0x1A11 ^ (seed << 5));
        let nprocs = 2 + rng.gen_usize(4); // 2..=5
        let len = 1 + rng.gen_usize(600);
        let segments = if rng.gen_usize(3) == 0 {
            "auto".to_string()
        } else {
            (1 + rng.gen_usize(9)).to_string()
        };
        let (arm, cfg) = match rng.gen_usize(4) {
            0 => (None, MpiConfig::optimized(5)),
            1 => (None, MpiConfig::striped_sharded(5)),
            2 => (Some("dedicated"), MpiConfig::optimized(5)),
            _ => (Some("striped"), MpiConfig::optimized(5)),
        };
        let root = rng.gen_usize(nprocs);
        let spec = ClusterSpec::new(
            FabricConfig {
                interconnect: Interconnect::Ib,
                nodes: nprocs,
                procs_per_node: 1,
                max_contexts_per_node: 64,
            },
            cfg,
            1,
        );
        let r = run_cluster(spec, move |proc, _t| {
            let world = proc.comm_world();
            let mut info = Info::new().with("vcmpi_coll_segments", segments.clone());
            if let Some(mode) = arm {
                info.set("vcmpi_collectives", mode);
            }
            let comm = proc.comm_dup_with_info(&world, &info);
            let n = proc.nprocs();
            let orig: Vec<f32> =
                (0..len).map(|i| ((proc.rank() * 1000 + i) % 97) as f32).collect();
            // Blocking reference (same engine, driven synchronously).
            let mut blocking = orig.clone();
            proc.allreduce_f32(&comm, &mut blocking);
            // Nonblocking, with compute between issue and wait.
            let req = proc.iallreduce_f32(&comm, &orig);
            vcmpi::sim::advance(10_000 + (seed % 7) * 3_000);
            let mut overlapped = vec![0.0f32; len];
            proc.coll_wait_f32(req, &mut overlapped);
            assert_eq!(
                overlapped, blocking,
                "seed {seed}: iallreduce must be bit-identical to blocking"
            );
            for (i, &v) in overlapped.iter().enumerate() {
                let want: f32 = (0..n).map(|r| ((r * 1000 + i) % 97) as f32).sum();
                assert!(
                    (v - want).abs() <= want.abs() * 1e-5 + 1e-3,
                    "seed {seed} i={i}: got {v}, want {want}"
                );
            }
            // Ibcast from a random root through the same policy.
            let payload: Vec<u8> = (0..(len % 181) + 1).map(|i| (i * 11 + root) as u8).collect();
            let breq = proc.ibcast(
                &comm,
                root,
                if proc.rank() == root { Some(payload.clone()) } else { None },
            );
            vcmpi::sim::advance(5_000);
            let got = proc.coll_wait(breq);
            assert_eq!(got, payload, "seed {seed}: ibcast mismatch");
            proc.comm_free(comm);
            proc.barrier(&world);
        });
        assert_eq!(r.outcome, SimOutcome::Completed, "seed {seed}");
    }
}

/// Mixed per-communicator policies against the single-engine oracle: one
/// process set hosts a striped+sharded comm, an ordered (`off`) comm, and
/// a wildcard-heavy hashed-striped comm — created from info keys on a
/// process whose global default is NOT striped — with two concurrent
/// threads per process driving them. The oracle is the same one
/// `prop_random_traffic_striped_*` uses: a single VCI delivers per-stream
/// FIFO by construction, so numbered payload streams must arrive exactly
/// once each, in order, on every comm, whatever mix of policies carried
/// them (wildcard receives may bind across sources but must preserve
/// per-source order and exactly-once delivery).
#[test]
fn prop_mixed_policy_comms_match_single_engine_oracle() {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};
    use vcmpi::platform::PBarrier;

    for seed in 0..cases(5) {
        let nprocs = 3usize;
        let spec = ClusterSpec::new(
            FabricConfig {
                interconnect: Interconnect::Opa,
                nodes: nprocs,
                procs_per_node: 1,
                max_contexts_per_node: 64,
            },
            MpiConfig::optimized(6), // process-global striping OFF
            2,
        );
        let comms: Arc<Mutex<HashMap<usize, Vec<vcmpi::mpi::Comm>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let bars: Arc<Vec<PBarrier>> =
            Arc::new((0..nprocs).map(|_| PBarrier::new(Backend::Sim, 2)).collect());
        let c2 = comms.clone();
        let r = run_cluster(spec, move |proc, t| {
            let world = proc.comm_world();
            let me = proc.rank();
            let n = proc.nprocs();
            if t == 0 {
                let hot = proc.comm_dup_with_info(
                    &world,
                    &Info::new()
                        .with("vcmpi_striping", "rr")
                        .with("vcmpi_match_shards", "4")
                        .with("vcmpi_rx_doorbell", "true"),
                );
                let cold = proc.comm_dup(&world);
                let wild = proc.comm_dup_with_info(
                    &world,
                    &Info::new()
                        .with("vcmpi_striping", "hash")
                        .with("vcmpi_match_shards", "2")
                        .with("vcmpi_wildcard_linger", "2"),
                );
                c2.lock().unwrap().insert(me, vec![hot, cold, wild]);
            }
            bars[me].wait();
            let v = c2.lock().unwrap().get(&me).unwrap().clone();
            let (hot, cold, wild) = (v[0].clone(), v[1].clone(), v[2].clone());
            let mut prng = SplitMix64::new(seed.wrapping_mul(0x9E37) ^ 0x31D);
            let per = 4 + prng.gen_usize(10); // msgs per (comm, src, dst)
            // Thread 0 drives the hot comm; thread 1 drives cold + wild,
            // concurrently — three policies live in one process at once.
            if t == 0 {
                let mut sreqs = Vec::new();
                for dst in 0..n {
                    if dst == me {
                        continue;
                    }
                    for k in 0..per as u32 {
                        sreqs.push(proc.isend(&hot, dst, 11, &k.to_le_bytes()));
                    }
                }
                for src in 0..n {
                    if src == me {
                        continue;
                    }
                    for k in 0..per as u32 {
                        let got = proc.recv(&hot, Src::Rank(src), Tag::Value(11));
                        let got = u32::from_le_bytes(got.as_slice().try_into().unwrap());
                        assert_eq!(got, k, "seed {seed}: hot stream {src}->{me} diverged");
                    }
                }
                proc.waitall(sreqs);
            } else {
                // Cold (ordered) comm: plain FIFO streams.
                let mut sreqs = Vec::new();
                for dst in 0..n {
                    if dst == me {
                        continue;
                    }
                    for k in 0..per as u32 {
                        sreqs.push(proc.isend(&cold, dst, 22, &k.to_le_bytes()));
                    }
                }
                for src in 0..n {
                    if src == me {
                        continue;
                    }
                    for k in 0..per as u32 {
                        let got = proc.recv(&cold, Src::Rank(src), Tag::Value(22));
                        let got = u32::from_le_bytes(got.as_slice().try_into().unwrap());
                        assert_eq!(got, k, "seed {seed}: cold stream {src}->{me} diverged");
                    }
                }
                proc.waitall(sreqs);
                // Wildcard-heavy comm: payload carries (src, k); a random
                // third of receives are MPI_ANY_SOURCE, so the epoch
                // protocol flips under fire. Track per-source counters —
                // exactly-once, in-order delivery per stream is the
                // single-engine oracle's guarantee.
                let mut sreqs = Vec::new();
                for dst in 0..n {
                    if dst == me {
                        continue;
                    }
                    for k in 0..per as u32 {
                        let mut data = vec![me as u8];
                        data.extend_from_slice(&k.to_le_bytes());
                        sreqs.push(proc.isend(&wild, dst, 33, &data));
                    }
                }
                let mut next = vec![0u32; n];
                let mut remaining: Vec<usize> =
                    (0..n).map(|s| if s == me { 0 } else { per }).collect();
                let mut rng = SplitMix64::new(seed ^ ((me as u64) << 16) ^ 0x77);
                let mut left: usize = remaining.iter().sum();
                while left > 0 {
                    let src_pat = if rng.gen_bool(0.34) {
                        Src::Any
                    } else {
                        // A concrete source that still has messages due.
                        let mut s = rng.gen_usize(n);
                        while remaining[s] == 0 {
                            s = (s + 1) % n;
                        }
                        Src::Rank(s)
                    };
                    let got = proc.recv(&wild, src_pat, Tag::Value(33));
                    let src = got[0] as usize;
                    let k = u32::from_le_bytes(got[1..5].try_into().unwrap());
                    assert_eq!(
                        k, next[src],
                        "seed {seed}: wild stream {src}->{me} lost/duplicated/reordered"
                    );
                    next[src] += 1;
                    assert!(remaining[src] > 0, "seed {seed}: overdelivery from {src}");
                    remaining[src] -= 1;
                    left -= 1;
                }
                proc.waitall(sreqs);
            }
            bars[me].wait();
            if t == 0 {
                proc.barrier(&world);
                let (dups, parked) = proc.reorder_stats();
                assert_eq!(dups, 0, "seed {seed}: wire traffic must never look duplicated");
                assert_eq!(parked, 0, "seed {seed}: reorder buffers drain by quiescence");
                assert_eq!(proc.policy_mismatch_count(), 0, "seed {seed}: wire contract");
                assert!(!proc.has_match_engine(v[1].id), "seed {seed}: cold comm sharded");
                // Free all three comms: exercises engine/cache teardown and
                // the finalize-time freed-comm assertion.
                for c in v.clone() {
                    proc.comm_free(c);
                }
            }
            bars[me].wait();
        });
        assert_eq!(r.outcome, SimOutcome::Completed, "seed {seed}");
    }
}

// ---------------------------------------------------------------------
// RMA: random put/get programs leave window memory in the expected state.
// ---------------------------------------------------------------------

#[test]
fn prop_rma_random_puts_land_exactly() {
    for seed in 0..cases(8) {
        for ic in [Interconnect::Ib, Interconnect::Opa] {
            let spec = ClusterSpec::new(
                FabricConfig {
                    interconnect: ic,
                    nodes: 2,
                    procs_per_node: 1,
                    max_contexts_per_node: 64,
                },
                MpiConfig::optimized(4),
                1,
            );
            let r = run_cluster(spec, move |proc, _t| {
                let world = proc.comm_world();
                let win = proc.win_create(&world, 4096);
                if proc.rank() == 0 {
                    let mut rng = SplitMix64::new(seed);
                    // Non-overlapping slots: slot i gets value derived from i.
                    let mut writes = Vec::new();
                    for slot in 0..16 {
                        if rng.gen_bool(0.7) {
                            let val = (seed as u8) ^ (slot as u8) | 0x40;
                            proc.put(&win, 1, slot * 64, &[val; 64]);
                            writes.push((slot, val));
                        }
                    }
                    proc.win_flush(&win);
                    let payload: Vec<u8> =
                        writes.iter().flat_map(|&(s, v)| [s as u8, v]).collect();
                    proc.send(&world, 1, 9, &payload);
                } else {
                    let manifest = proc.recv(&world, vcmpi::mpi::Src::Rank(0), vcmpi::mpi::Tag::Value(9));
                    for pair in manifest.chunks_exact(2) {
                        let (slot, val) = (pair[0] as usize, pair[1]);
                        assert_eq!(
                            win.read_local(slot * 64, 64),
                            vec![val; 64],
                            "seed {seed} {ic:?} slot {slot}"
                        );
                    }
                }
                proc.barrier(&world);
                proc.win_free(&world, win);
            });
            assert_eq!(r.outcome, SimOutcome::Completed);
        }
    }
}

// ---------------------------------------------------------------------
// RMA: a striped window must agree with the ordered single-VCI window on
// the final window bytes for commutative programs — while a striped
// communicator's p2p storm shares the pool (the mixed case).
// ---------------------------------------------------------------------

#[test]
fn prop_rma_striped_vs_ordered_window_oracle() {
    use vcmpi::fabric::AccOp;
    for seed in 0..cases(6) {
        let stripe_mode = if seed % 2 == 0 { "rr" } else { "hash" };
        let spec = ClusterSpec::new(
            FabricConfig {
                interconnect: Interconnect::Opa,
                nodes: 2,
                procs_per_node: 1,
                max_contexts_per_node: 64,
            },
            MpiConfig::optimized(6),
            2,
        );
        use std::collections::HashMap;
        use std::sync::{Arc, Mutex};
        type Shared = (vcmpi::mpi::Comm, Arc<vcmpi::mpi::Window>, Arc<vcmpi::mpi::Window>);
        let shared: Arc<Mutex<HashMap<usize, Shared>>> = Arc::new(Mutex::new(HashMap::new()));
        let bars: Arc<Vec<vcmpi::platform::PBarrier>> = Arc::new(
            (0..2)
                .map(|_| vcmpi::platform::PBarrier::new(vcmpi::platform::Backend::Sim, 2))
                .collect(),
        );
        const WIN_BYTES: usize = 256; // 32 u64 cells
        let r = run_cluster(spec, move |proc, t| {
            let world = proc.comm_world();
            let me = proc.rank();
            if t == 0 {
                // Symmetric creation order on both ranks: striped comm,
                // ordered window, striped window.
                let hot = proc.comm_dup_with_info(
                    &world,
                    &Info::new().with("vcmpi_striping", "rr").with("vcmpi_match_shards", "4"),
                );
                let ordered = proc.win_create(&world, WIN_BYTES);
                let striped = proc.win_create_with_info(
                    &world,
                    WIN_BYTES,
                    &Info::new()
                        .with("accumulate_ordering", "none")
                        .with("vcmpi_striping", stripe_mode)
                        .with("vcmpi_rx_doorbell", "true"),
                );
                shared.lock().unwrap().insert(me, (hot, ordered, striped));
            }
            bars[me].wait();
            let (hot, ordered, striped) = shared.lock().unwrap().get(&me).unwrap().clone();
            if t == 1 {
                // Concurrent striped p2p storm on the shared pool.
                if me == 0 {
                    let reqs: Vec<_> =
                        (0..48).map(|_| proc.isend(&hot, 1, 3, &[0u8; 24])).collect();
                    proc.waitall(reqs);
                } else {
                    let reqs: Vec<_> = (0..48)
                        .map(|_| proc.irecv(&hot, Src::Rank(0), Tag::Value(3)))
                        .collect();
                    proc.waitall(reqs);
                }
            } else if me == 0 {
                // Same random commutative program against BOTH windows:
                // put-once slots (each written exactly once) + wrapping
                // u64-sum accumulates (commutative AND associative, so
                // any apply order yields identical bytes — f64 would
                // not). `expected` is the independently computed oracle.
                let mut rng = SplitMix64::new(seed.wrapping_mul(0x9E37) ^ 0xABCD);
                let mut expected = vec![0u8; WIN_BYTES];
                let nput = rng.gen_usize(8);
                for slot in 0..nput {
                    let val = [(seed as u8) ^ (slot as u8) | 0x11; 8];
                    proc.put(&ordered, 1, slot * 8, &val);
                    proc.put(&striped, 1, slot * 8, &val);
                    expected[slot * 8..slot * 8 + 8].copy_from_slice(&val);
                }
                let nacc = 20 + rng.gen_usize(40);
                for i in 0..nacc {
                    let cell = nput + rng.gen_usize(32 - nput);
                    let add = rng.next_u64();
                    proc.accumulate(&ordered, 1, cell * 8, &add.to_le_bytes(), AccOp::SumU64);
                    proc.accumulate(&striped, 1, cell * 8, &add.to_le_bytes(), AccOp::SumU64);
                    let o = cell * 8;
                    let cur = u64::from_le_bytes(expected[o..o + 8].try_into().unwrap());
                    expected[o..o + 8].copy_from_slice(&cur.wrapping_add(add).to_le_bytes());
                    if i % 16 == 15 {
                        // Interleave flushes: watermarks must stay correct
                        // across flush boundaries.
                        proc.win_flush(&striped);
                    }
                }
                proc.win_flush(&ordered);
                proc.win_flush(&striped);
                proc.send(&world, 1, 9, &expected);
            } else {
                let expected = proc.recv(&world, Src::Rank(0), Tag::Value(9));
                assert_eq!(
                    ordered.read_local(0, WIN_BYTES),
                    expected,
                    "seed {seed}: ordered window diverged from the oracle"
                );
                assert_eq!(
                    striped.read_local(0, WIN_BYTES),
                    expected,
                    "seed {seed} ({stripe_mode}): striped window diverged from the oracle"
                );
            }
            bars[me].wait();
            if t == 0 {
                proc.barrier(&world);
                assert_eq!(proc.policy_mismatch_count(), 0, "seed {seed}: wire contract");
                let (hot, ordered, striped) = { shared.lock().unwrap().remove(&me).unwrap() };
                proc.win_free(&world, ordered);
                proc.win_free(&world, striped);
                proc.comm_free(hot);
            }
            bars[me].wait();
        });
        assert_eq!(r.outcome, SimOutcome::Completed, "seed {seed}");
    }
}

// ---------------------------------------------------------------------
// RMA passive target: the same random commutative program synchronized
// with lock epochs (shared epochs on the striped window, exclusive
// epochs on the ordered window) must land the exact bytes the flush
// arm above lands — both are checked against the same independently
// computed oracle, so epoch-based completion (unlock = per-target
// flush) and flush-based completion are interchangeable for data.
// ---------------------------------------------------------------------

#[test]
fn prop_passive_vs_flush_oracle() {
    use vcmpi::fabric::AccOp;
    use vcmpi::mpi::LockKind;
    for seed in 0..cases(6) {
        // Alternate interconnect (OPA active-message locks vs IB
        // NIC-atomic lock words) and stripe mode by seed.
        let ic = if seed % 2 == 0 { Interconnect::Opa } else { Interconnect::Ib };
        let stripe_mode = if (seed / 2) % 2 == 0 { "rr" } else { "hash" };
        let spec = ClusterSpec::new(
            FabricConfig {
                interconnect: ic,
                nodes: 2,
                procs_per_node: 1,
                max_contexts_per_node: 64,
            },
            MpiConfig::optimized(6),
            2,
        );
        use std::collections::HashMap;
        use std::sync::{Arc, Mutex};
        type Shared = (vcmpi::mpi::Comm, Arc<vcmpi::mpi::Window>, Arc<vcmpi::mpi::Window>);
        let shared: Arc<Mutex<HashMap<usize, Shared>>> = Arc::new(Mutex::new(HashMap::new()));
        let bars: Arc<Vec<vcmpi::platform::PBarrier>> = Arc::new(
            (0..2)
                .map(|_| vcmpi::platform::PBarrier::new(vcmpi::platform::Backend::Sim, 2))
                .collect(),
        );
        const WIN_BYTES: usize = 256; // 32 u64 cells
        let r = run_cluster(spec, move |proc, t| {
            let world = proc.comm_world();
            let me = proc.rank();
            if t == 0 {
                // Symmetric creation order on both ranks: striped comm,
                // ordered window, striped window.
                let hot = proc.comm_dup_with_info(
                    &world,
                    &Info::new().with("vcmpi_striping", "rr").with("vcmpi_match_shards", "4"),
                );
                let ordered = proc.win_create(&world, WIN_BYTES);
                let striped = proc.win_create_with_info(
                    &world,
                    WIN_BYTES,
                    &Info::new()
                        .with("accumulate_ordering", "none")
                        .with("vcmpi_striping", stripe_mode)
                        .with("vcmpi_rx_doorbell", "true"),
                );
                shared.lock().unwrap().insert(me, (hot, ordered, striped));
            }
            bars[me].wait();
            let (hot, ordered, striped) = shared.lock().unwrap().get(&me).unwrap().clone();
            if t == 1 {
                // Concurrent striped p2p storm on the shared pool.
                if me == 0 {
                    let reqs: Vec<_> =
                        (0..48).map(|_| proc.isend(&hot, 1, 3, &[0u8; 24])).collect();
                    proc.waitall(reqs);
                } else {
                    let reqs: Vec<_> = (0..48)
                        .map(|_| proc.irecv(&hot, Src::Rank(0), Tag::Value(3)))
                        .collect();
                    proc.waitall(reqs);
                }
            } else if me == 0 {
                // Generate the op list up front (put-once slots + wrapping
                // u64-sum accumulates: commutative AND associative, so any
                // apply order yields identical bytes), compute the oracle,
                // then REPLAY the ops inside lock epochs instead of with
                // win_flush: exclusive epochs on the ordered window,
                // shared epochs on the striped window, one epoch pair per
                // batch so completion happens only at win_unlock.
                let mut rng = SplitMix64::new(seed.wrapping_mul(0x51ED) ^ 0x7777);
                let mut expected = vec![0u8; WIN_BYTES];
                let nput = rng.gen_usize(8);
                let mut ops: Vec<(usize, u64, bool)> = Vec::new(); // (cell, val, is_put)
                for slot in 0..nput {
                    let b = ((seed as u8) ^ (slot as u8)) | 0x11;
                    ops.push((slot, u64::from_le_bytes([b; 8]), true));
                }
                let nacc = 20 + rng.gen_usize(40);
                for _ in 0..nacc {
                    let cell = nput + rng.gen_usize(32 - nput);
                    ops.push((cell, rng.next_u64(), false));
                }
                for &(cell, val, is_put) in &ops {
                    let o = cell * 8;
                    if is_put {
                        expected[o..o + 8].copy_from_slice(&val.to_le_bytes());
                    } else {
                        let cur = u64::from_le_bytes(expected[o..o + 8].try_into().unwrap());
                        expected[o..o + 8].copy_from_slice(&cur.wrapping_add(val).to_le_bytes());
                    }
                }
                for batch in ops.chunks(12) {
                    proc.win_lock(&ordered, LockKind::Exclusive, 1);
                    proc.win_lock(&striped, LockKind::Shared, 1);
                    for &(cell, val, is_put) in batch {
                        if is_put {
                            proc.put(&ordered, 1, cell * 8, &val.to_le_bytes());
                            proc.put(&striped, 1, cell * 8, &val.to_le_bytes());
                        } else {
                            let add = val.to_le_bytes();
                            proc.accumulate(&ordered, 1, cell * 8, &add, AccOp::SumU64);
                            proc.accumulate(&striped, 1, cell * 8, &add, AccOp::SumU64);
                        }
                    }
                    // flush_local inside an epoch is legal and must not
                    // disturb the unlock's remote completion.
                    proc.win_flush_local(&striped);
                    proc.win_unlock(&ordered, 1);
                    proc.win_unlock(&striped, 1);
                }
                proc.send(&world, 1, 9, &expected);
            } else {
                let expected = proc.recv(&world, Src::Rank(0), Tag::Value(9));
                assert_eq!(
                    ordered.read_local(0, WIN_BYTES),
                    expected,
                    "seed {seed} ({ic:?}): exclusive-epoch ordered window diverged"
                );
                assert_eq!(
                    striped.read_local(0, WIN_BYTES),
                    expected,
                    "seed {seed} ({ic:?}, {stripe_mode}): shared-epoch striped window diverged"
                );
            }
            bars[me].wait();
            if t == 0 {
                proc.barrier(&world);
                assert_eq!(proc.policy_mismatch_count(), 0, "seed {seed}: wire contract");
                let (hot, ordered, striped) = { shared.lock().unwrap().remove(&me).unwrap() };
                proc.win_free(&world, ordered);
                proc.win_free(&world, striped);
                proc.comm_free(hot);
            }
            bars[me].wait();
        });
        assert_eq!(r.outcome, SimOutcome::Completed, "seed {seed} ({ic:?})");
    }
}

// ---------------------------------------------------------------------
// Determinism: identical seeds -> bit-identical virtual end times.
// ---------------------------------------------------------------------

#[test]
fn prop_simulation_is_deterministic() {
    let run = || {
        let spec = ClusterSpec::new(
            FabricConfig {
                interconnect: Interconnect::Opa,
                nodes: 2,
                procs_per_node: 1,
                max_contexts_per_node: 64,
            },
            MpiConfig::optimized(8),
            4,
        );
        let r = run_cluster(spec, |proc, t| {
            let world = proc.comm_world();
            let peer = 1 - proc.rank();
            for i in 0..40 {
                let sreq = proc.isend(&world, peer, t as i32, &[i; 32]);
                let rreq = proc.irecv(
                    &world,
                    vcmpi::mpi::Src::Rank(peer),
                    vcmpi::mpi::Tag::Value(t as i32),
                );
                proc.wait(rreq);
                proc.wait(sreq);
            }
        });
        assert_eq!(r.outcome, SimOutcome::Completed);
        r.time_ns
    };
    let a = run();
    let b = run();
    let c = run();
    assert_eq!(a, b);
    assert_eq!(b, c);
}
