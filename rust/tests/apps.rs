//! Application-driver integration tests: each paper application completes
//! on realistic (scaled-down) topologies and reproduces its figure's
//! qualitative shape.

use vcmpi::apps::bspmm::{run_bspmm, BspmmParams};
use vcmpi::apps::ebms::{fetch_time, EbmsParams};
use vcmpi::apps::stencil::{halo_time, StencilParams};
use vcmpi::apps::AppMode;
use vcmpi::fabric::Interconnect;

#[test]
fn fig22_shape_par_comm_close_to_everywhere() {
    // Paper: par_comm+vcis halo time matches MPI everywhere (within noise)
    // and beats the original library.
    let mk = |mode| StencilParams {
        mode,
        nodes_x: 2,
        nodes_y: 2,
        tx: 2,
        ty: 2,
        mesh: 1024,
        iters: 3,
        ..Default::default()
    };
    let ew = halo_time(mk(AppMode::Everywhere));
    let par = halo_time(mk(AppMode::ParCommVcis));
    let orig = halo_time(mk(AppMode::ParCommOrig));
    let ep = halo_time(mk(AppMode::Endpoints));
    assert!(par < orig, "multi-VCI ({par}) must beat original ({orig})");
    assert!(par < 3.0 * ew, "par_comm ({par}) should be in everywhere's ({ew}) ballpark");
    assert!(ep < orig, "endpoints ({ep}) must beat original ({orig})");
}

#[test]
fn fig24_shape_ib_fetch_flat_opa_fetch_slow() {
    let mk = |ic, mode| EbmsParams {
        mode,
        interconnect: ic,
        nodes: 2,
        threads: 4,
        fetch_bytes: 32 * 1024,
        iters: 3,
        compute_ns: 30_000,
        ..Default::default()
    };
    // On IB, par_comm fetch ~= everywhere fetch (hardware RMA).
    let (g_ew, f_ew) = fetch_time(mk(Interconnect::Ib, AppMode::Everywhere));
    let (g_par, f_par) = fetch_time(mk(Interconnect::Ib, AppMode::ParCommVcis));
    let ib_ew = g_ew + f_ew;
    let ib_par = g_par + f_par;
    assert!(
        ib_par < 3.0 * ib_ew,
        "IB par fetch ({ib_par}) should be close to everywhere ({ib_ew})"
    );
    // On OPA, the flush (not the get) dominates for par_comm (Fig. 25).
    let (g_opa, f_opa) = fetch_time(mk(Interconnect::Opa, AppMode::ParCommVcis));
    assert!(
        f_opa > g_opa,
        "software-RMA flush ({f_opa}) should dominate get ({g_opa})"
    );
}

#[test]
fn fig27_shape_endpoints_beat_single_window_accumulates() {
    let mk = |mode, relaxed| BspmmParams {
        mode,
        nodes: 2,
        threads: 4,
        tile_dim: 128,
        units_per_worker: 2,
        relaxed_acc: relaxed,
        ..Default::default()
    };
    let par = run_bspmm(mk(AppMode::ParCommVcis, false));
    let ep = run_bspmm(mk(AppMode::Endpoints, false));
    let relaxed = run_bspmm(mk(AppMode::ParCommVcis, true));
    // All three complete and report sane per-phase times; the quantitative
    // 16-thread comparison is the fig27 CSV (`repro figures fig27`) — at
    // this mini-scale per-phase samples are too few for ratio assertions.
    for (label, t) in [("par", &par), ("ep", &ep), ("relaxed", &relaxed)] {
        assert!(t.get_init > 0.0, "{label}: get_init");
        assert!(t.get_flush >= 0.0, "{label}: get_flush");
        assert!(t.acc_init > 0.0, "{label}: acc_init");
        assert!(t.acc_flush >= 0.0, "{label}: acc_flush");
    }
}

#[test]
fn stencil_modes_ordering_is_stable_across_meshes() {
    for mesh in [512, 2048] {
        let mk = |mode| StencilParams {
            mode,
            nodes_x: 2,
            nodes_y: 1,
            tx: 2,
            ty: 2,
            mesh,
            iters: 2,
            ..Default::default()
        };
        let par = halo_time(mk(AppMode::ParCommVcis));
        let orig = halo_time(mk(AppMode::ParCommOrig));
        assert!(par <= orig * 1.05, "mesh {mesh}: par {par} vs orig {orig}");
    }
}
