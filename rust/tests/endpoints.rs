//! User-visible MPI Endpoints: the comparison arm. Endpoint ranks address
//! (process, VCI) pairs directly.

use std::sync::{Arc, Mutex};

use vcmpi::fabric::{FabricConfig, Interconnect};
use vcmpi::mpi::{run_cluster, ClusterSpec, Comm, MpiConfig, MpiProc, Src, Tag};
use vcmpi::platform::{Backend, PBarrier};
use vcmpi::sim::SimOutcome;

fn spec(threads: usize, nvcis: usize) -> ClusterSpec {
    ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Ib,
            nodes: 2,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        MpiConfig::optimized(nvcis),
        threads,
    )
}

fn run_ok(s: ClusterSpec, body: impl Fn(&Arc<MpiProc>, usize) + Send + Sync + 'static) {
    let r = run_cluster(s, body);
    assert_eq!(r.outcome, SimOutcome::Completed, "{:?}", r.outcome);
}

/// Helper: thread 0 creates the endpoints comm; all threads share it.
fn with_endpoints(
    threads: usize,
    nvcis: usize,
    per_proc: usize,
    body: impl Fn(&Arc<MpiProc>, usize, &Comm) + Send + Sync + 'static,
) {
    let shared: Arc<Mutex<std::collections::HashMap<usize, Comm>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let bars: Arc<Vec<PBarrier>> =
        Arc::new((0..2).map(|_| PBarrier::new(Backend::Sim, threads)).collect());
    let s2 = shared.clone();
    run_ok(spec(threads, nvcis), move |proc, t| {
        if t == 0 {
            let world = proc.comm_world();
            let ep = proc.create_endpoints(&world, per_proc);
            s2.lock().unwrap().insert(proc.rank(), ep);
        }
        bars[proc.rank()].wait();
        let ep = s2.lock().unwrap().get(&proc.rank()).unwrap().clone();
        body(proc, t, &ep);
        bars[proc.rank()].wait();
    });
}

#[test]
fn endpoint_pairs_exchange_directly() {
    // 4 threads x 2 procs; thread t uses endpoint t and talks to the same
    // endpoint on the peer process.
    with_endpoints(4, 8, 4, |proc, t, ep| {
        let peer_proc = 1 - proc.rank();
        let my_rank = proc.endpoint_rank(ep, proc.rank(), t);
        let peer_rank = proc.endpoint_rank(ep, peer_proc, t);
        let sreq = proc.isend_ep(ep, Some(t), peer_rank, t as i32, &[t as u8; 8], false);
        let rreq = proc.irecv_ep(ep, Some(t), Src::Rank(peer_rank), Tag::Value(t as i32));
        let got = proc.wait(rreq).unwrap();
        proc.wait(sreq);
        assert_eq!(got, vec![t as u8; 8]);
        let _ = my_rank;
    });
}

#[test]
fn endpoints_demand_distinct_vcis() {
    // Asking for more endpoints than the pool has VCIs must fail loudly
    // (endpoints expose hardware limits — that's their point).
    let result = std::panic::catch_unwind(|| {
        with_endpoints(1, 2, 4, |_proc, _t, _ep| {});
    });
    assert!(result.is_err(), "endpoint over-subscription should panic");
}

#[test]
fn cross_endpoint_addressing() {
    // Any endpoint can send to any other endpoint rank, not just its twin.
    with_endpoints(2, 8, 2, |proc, t, ep| {
        let peer_proc = 1 - proc.rank();
        // Thread t sends to peer endpoint (1 - t): a crossed pattern.
        let to = proc.endpoint_rank(ep, peer_proc, 1 - t);
        let sreq = proc.isend_ep(ep, Some(t), to, 77, &[proc.rank() as u8, t as u8], false);
        // And receives whatever lands on ITS endpoint.
        let rreq = proc.irecv_ep(ep, Some(t), Src::Any, Tag::Value(77));
        let got = proc.wait(rreq).unwrap();
        proc.wait(sreq);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0] as usize, peer_proc, "from the peer process");
        assert_eq!(got[1] as usize, 1 - t, "from the crossed endpoint");
    });
}

#[test]
fn endpoints_and_world_coexist() {
    with_endpoints(2, 8, 2, |proc, t, ep| {
        let world = proc.comm_world();
        let peer_proc = 1 - proc.rank();
        if t == 0 {
            // World traffic alongside endpoint traffic.
            let sreq = proc.isend(&world, peer_proc, 5, b"world");
            let rreq = proc.irecv(&world, Src::Rank(peer_proc), Tag::Value(5));
            let got = proc.wait(rreq).unwrap();
            proc.wait(sreq);
            assert_eq!(got, b"world");
        }
        let to = proc.endpoint_rank(ep, peer_proc, t);
        let sreq = proc.isend_ep(ep, Some(t), to, 6, b"ep", false);
        let rreq = proc.irecv_ep(ep, Some(t), Src::Rank(to), Tag::Value(6));
        let got = proc.wait(rreq).unwrap();
        proc.wait(sreq);
        assert_eq!(got, b"ep");
    });
}
