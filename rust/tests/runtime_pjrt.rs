//! PJRT runtime integration: load the real artifacts, execute, and check
//! numerics against closed-form expectations. Requires `make artifacts`
//! AND a build with the `pjrt` feature (the offline default build uses a
//! stub runtime, so this whole suite is compiled out).
#![cfg(feature = "pjrt")]

use vcmpi::runtime::{Runtime, Tensor};

fn runtime() -> Runtime {
    Runtime::open(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .expect("run `make artifacts` first")
}

#[test]
fn manifest_lists_all_graphs() {
    let rt = runtime();
    for name in [
        "train_grad_step",
        "train_sgd_step",
        "train_loss",
        "bspmm_tile",
        "stencil_block",
        "ebms_band",
    ] {
        assert!(rt.manifest.entry(name).is_some(), "{name} missing");
    }
    assert!(rt.manifest.config("param_count").unwrap() > 1_000_000);
}

#[test]
fn sgd_step_is_axpy() {
    let rt = runtime();
    let exe = rt.load("train_sgd_step").unwrap();
    let p = rt.manifest.config("param_count").unwrap() as usize;
    let params = Tensor::f32(&[p], vec![1.0; p]);
    let grads = Tensor::f32(&[p], vec![0.5; p]);
    let lr = Tensor::scalar_f32(0.2);
    let out = exe.run(&[params, grads, lr]).unwrap();
    let new = out[0].as_f32();
    assert!(new.iter().all(|&x| (x - 0.9).abs() < 1e-6), "1.0 - 0.2*0.5 = 0.9");
}

#[test]
fn bspmm_tile_is_mac() {
    let rt = runtime();
    let exe = rt.load("bspmm_tile").unwrap();
    // A = I, B = 2s, C = 1s  =>  C + A@B = 1 + 2 = 3 everywhere.
    let mut a = vec![0.0f32; 128 * 128];
    for i in 0..128 {
        a[i * 128 + i] = 1.0;
    }
    let b = Tensor::f32(&[128, 128], vec![2.0; 128 * 128]);
    let c = Tensor::f32(&[128, 128], vec![1.0; 128 * 128]);
    let out = exe.run(&[Tensor::f32(&[128, 128], a), b, c]).unwrap();
    assert!(out[0].as_f32().iter().all(|&x| (x - 3.0).abs() < 1e-5));
}

#[test]
fn stencil_block_matches_formula() {
    let rt = runtime();
    let exe = rt.load("stencil_block").unwrap();
    // u(i,j) = i: neighbors avg = i, update = i - i = ... N+S+E+W = (i-1)+(i+1)+i+i = 4i
    // => 0.25*4i - i = 0.
    let mut u = vec![0.0f32; 66 * 66];
    for i in 0..66 {
        for j in 0..66 {
            u[i * 66 + j] = i as f32;
        }
    }
    let out = exe.run(&[Tensor::f32(&[66, 66], u)]).unwrap();
    assert!(out[0].as_f32().iter().all(|&x| x.abs() < 1e-5));
}

#[test]
fn ebms_band_attenuates() {
    let rt = runtime();
    let exe = rt.load("ebms_band").unwrap();
    let xs = Tensor::f32(&[4096], vec![1.0; 4096]);
    let idx = Tensor::i32(&[2048], (0..2048).collect());
    let dist = Tensor::f32(&[2048], vec![0.0; 2048]);
    let out = exe.run(&[xs, idx, dist]).unwrap();
    assert!(out[0].as_f32().iter().all(|&x| (x - 1.0).abs() < 1e-6), "exp(0) = 1");
}

#[test]
fn grad_step_loss_starts_near_uniform() {
    let rt = runtime();
    let exe = rt.load("train_grad_step").unwrap();
    let p = rt.manifest.config("param_count").unwrap() as usize;
    let b = rt.manifest.config("batch").unwrap() as usize;
    let t = rt.manifest.config("seq").unwrap() as usize;
    let vocab = rt.manifest.config("vocab").unwrap() as i32;
    // Small deterministic init.
    let params: Vec<f32> =
        (0..p).map(|i| ((i as f32 * 0.6180339887).fract() - 0.5) * 0.04).collect();
    let tokens: Vec<i32> = (0..b * t).map(|i| (i as i32 * 7 + 3) % vocab).collect();
    let out = exe
        .run(&[Tensor::f32(&[p], params), Tensor::i32(&[b, t], tokens)])
        .unwrap();
    let loss = out[0].as_f32()[0];
    let uniform = (vocab as f32).ln();
    assert!(
        (loss - uniform).abs() < 1.0,
        "fresh-model loss {loss} should be near ln(V) = {uniform}"
    );
    let grads = out[1].as_f32();
    assert_eq!(grads.len(), p);
    assert!(grads.iter().all(|g| g.is_finite()));
    assert!(grads.iter().any(|&g| g.abs() > 1e-8), "gradients must be nonzero");
}

#[test]
fn shape_mismatch_is_rejected() {
    let rt = runtime();
    let exe = rt.load("stencil_block").unwrap();
    let bad = Tensor::f32(&[10, 10], vec![0.0; 100]);
    assert!(exe.run(&[bad]).is_err());
}
