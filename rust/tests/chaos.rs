//! Chaos lane: deterministic fabric fault injection (`vcmpi_fault_plan`)
//! exercised end to end. Every arm runs a seeded per-link fault schedule
//! — drops, duplicates, corruption, reorder-delays, hard context kills —
//! against the same exactly-once / FIFO-per-stream oracle the fault-free
//! property tests use, and asserts the reliability counters actually
//! fired (a chaos run that injected nothing proves nothing).
//!
//! Determinism contract: a `FaultPlan` rolls every decision from a
//! SplitMix stream keyed by (seed, link, seq, attempt), so one plan
//! string produces the same faults at the same points on every run —
//! `chaos_replay_is_bit_for_bit` pins that down to the virtual end time
//! and the full measurement map.
//!
//! Case counts scale with `PROPTEST_CASES` (CI: small on PRs, large on
//! the nightly soak), like `proptests.rs`.

use std::sync::Arc;

use vcmpi::fabric::{
    FabricConfig, FaultPlan, Interconnect, Network, Payload, RelHeader, WireMsg,
};
use vcmpi::mpi::{run_cluster, ClusterSpec, MpiConfig, RunReport, Src, Tag};
use vcmpi::platform::Backend;
use vcmpi::sim::{CostModel, SimOutcome};
use vcmpi::util::SplitMix64;

/// Seed count for one property: `PROPTEST_CASES` if set, else `default`.
fn cases(default: u64) -> u64 {
    std::env::var("PROPTEST_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Run the standard chaos storm under `plan`: numbered p2p streams
/// (exactly-once, FIFO per stream — the oracle is the in-order assert),
/// an allreduce against the host-computed sum, and a closing barrier.
fn chaos_storm(plan: &str, mut cfg: MpiConfig, nprocs: usize, msgs: usize) -> RunReport {
    cfg.fault_plan = Some(plan.to_string());
    let spec = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Opa,
            nodes: nprocs,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        cfg,
        1,
    );
    run_cluster(spec, move |proc, _t| {
        let world = proc.comm_world();
        let me = proc.rank();
        let n = proc.nprocs();
        // Deterministic per-rank payload sizes spanning immediate + eager.
        let mut rng = SplitMix64::new(0xC4A0 ^ (me as u64));
        let mut sreqs = Vec::new();
        for dst in 0..n {
            if dst == me {
                continue;
            }
            for k in 0..msgs {
                let size = 8 + rng.gen_usize(1500);
                let mut data = vec![(k % 251) as u8; size];
                data[0] = k as u8;
                sreqs.push(proc.isend(&world, dst, 5, &data));
            }
        }
        for src in 0..n {
            if src == me {
                continue;
            }
            for k in 0..msgs {
                let got = proc.recv(&world, Src::Rank(src), Tag::Value(5));
                assert_eq!(
                    got[0], k as u8,
                    "stream {src}->{me} lost/duplicated/reordered under faults"
                );
            }
        }
        proc.waitall(sreqs);
        // A collective through the same faulted fabric.
        let mut data: Vec<f32> = (0..64).map(|i| (me * 100 + i) as f32).collect();
        proc.allreduce_f32(&world, &mut data);
        for (i, &v) in data.iter().enumerate() {
            let want: f32 = (0..n).map(|r| (r * 100 + i) as f32).sum();
            assert!((v - want).abs() < 1e-3, "allreduce[{i}] diverged under faults");
        }
        proc.barrier(&world);
    })
}

fn stat(r: &RunReport, key: &str) -> f64 {
    *r.measurements.get(key).unwrap_or_else(|| {
        panic!("fault counter `{key}` missing from measurements: a plan was installed")
    })
}

/// The determinism pin: the same seeded plan twice must produce an
/// identical run — same outcome, bit-identical virtual end time, and an
/// identical measurement map including every fault counter.
#[test]
fn chaos_replay_is_bit_for_bit() {
    let plan = "seed=42,drop=40,dup=15,corrupt=20,delay=25,delay_ns=30000";
    let run = || chaos_storm(plan, MpiConfig::optimized(6), 3, 20);
    let a = run();
    let b = run();
    assert_eq!(a.outcome, SimOutcome::Completed);
    assert_eq!(b.outcome, SimOutcome::Completed);
    assert_eq!(a.time_ns, b.time_ns, "virtual end time must replay bit-for-bit");
    assert_eq!(a.measurements, b.measurements, "measurements (incl. fault counters) must replay");
    // A replay of a fault-free run proves nothing.
    assert!(stat(&a, "fault_drops") > 0.0, "plan never dropped a frame");
    assert!(stat(&a, "fault_corrupts") > 0.0, "plan never corrupted a frame");
    assert!(stat(&a, "fault_retransmits") > 0.0, "nothing was ever retransmitted");
}

/// Drop-heavy arm: 8% of frames (plus reorder-delays) vanish on first
/// transmission; the retransmit path must recover every one, and the
/// storm's exactly-once / FIFO oracle must hold.
#[test]
fn chaos_drop_heavy_storm_completes() {
    let r = chaos_storm("seed=11,drop=80,delay=40", MpiConfig::optimized(6), 2, 40);
    assert_eq!(r.outcome, SimOutcome::Completed);
    assert!(stat(&r, "fault_drops") > 0.0);
    assert!(stat(&r, "fault_delays") > 0.0);
    assert!(stat(&r, "fault_retransmits") > 0.0, "drops must force retransmissions");
}

/// Corrupt-heavy arm: bit-flipped frames must be caught by the checksum
/// and dropped-and-counted (never panicking a decoder), duplicates must
/// be deduplicated, and the oracle must hold.
#[test]
fn chaos_corrupt_heavy_storm_completes() {
    let r = chaos_storm("seed=22,corrupt=80,dup=40", MpiConfig::optimized(6), 2, 40);
    assert_eq!(r.outcome, SimOutcome::Completed);
    assert!(stat(&r, "fault_corrupts") > 0.0);
    assert!(stat(&r, "fault_dups") > 0.0);
    assert!(
        stat(&r, "fault_rel_corrupt_drops") > 0.0,
        "corrupted frames must be dropped by the receiver's checksum"
    );
}

/// Context-kill arm: proc 1's hardware context 2 is dead from the first
/// instant, under background drops, on a *striped* pool (so the dead
/// lane provably carries traffic). The run must complete via transparent
/// lane failover — quarantine, state migration, redirect — and the
/// Table-1 failover counter must show it happened.
#[test]
fn chaos_context_kill_fails_over() {
    let before = vcmpi::mpi::instrument::proc_counters().failovers;
    let r = chaos_storm("seed=33,drop=40,kill=1:2@1", MpiConfig::striped(6), 2, 40);
    assert_eq!(r.outcome, SimOutcome::Completed, "a dead lane must fail over, not hang");
    let after = vcmpi::mpi::instrument::proc_counters().failovers;
    assert!(after > before, "completion without a recorded lane failover");
    assert!(stat(&r, "fault_drops") > 0.0);
}

/// Replay of the kill arm: failover decisions (survivor choice, migration
/// order) are part of the deterministic schedule too.
#[test]
fn chaos_context_kill_replay_is_bit_for_bit() {
    let run = || chaos_storm("seed=77,drop=30,kill=0:1@1", MpiConfig::striped(4), 2, 24);
    let a = run();
    let b = run();
    assert_eq!(a.outcome, SimOutcome::Completed);
    assert_eq!(a.time_ns, b.time_ns, "failover must not break replay determinism");
    assert_eq!(a.measurements, b.measurements);
}

/// Wire-decoder fuzz (receiver side, fabric level): a storm of forged
/// and corrupted frames — wrong checksums, bit-flipped payloads, stale
/// and future sequence numbers, duplicated valid frames, forged NIC
/// `RelAck`s with garbage channel ids — is delivered straight into a
/// context and polled through the reliable-delivery admission point.
/// The decoder must never panic, must admit exactly the valid frames in
/// sequence order, and must count every drop.
#[test]
fn prop_forged_frames_drop_and_count_never_panic() {
    for seed in 0..cases(60) {
        let mut rng = SplitMix64::new(0xF0A6 ^ seed.wrapping_mul(0x9E37));
        let net = Network::new(
            FabricConfig {
                interconnect: Interconnect::Opa,
                nodes: 2,
                procs_per_node: 1,
                max_contexts_per_node: 8,
            },
            Backend::Native,
            Arc::new(CostModel::default()),
        );
        net.install_fault_plan(Arc::new(FaultPlan::parse("seed=1").expect("plan parses")));
        let tx = net.proc_fabric(0);
        let rx = net.proc_fabric(1);
        let (src_ctx, _) = tx.open_context().expect("tx context");
        let (dst_idx, dst_ctx) = rx.open_context().expect("rx context");

        let payload_for = |seq: u64| Payload::RmaPut {
            win: 7,
            offset: seq as usize,
            data: vec![seq as u8; 16],
            flush_handle: seq,
            lane: None,
        };
        let frame = |seq: u64, checksum: u64, payload: Payload| WireMsg {
            arrival: 0,
            src_proc: 0,
            src_ctx,
            rel: Some(RelHeader { seq, checksum, ack: 0, chan_dst_ctx: dst_idx as u32 }),
            payload,
        };

        let nvalid = 1 + rng.gen_usize(20) as u64;
        let mut frames: Vec<WireMsg> = Vec::new();
        for seq in 1..=nvalid {
            let p = payload_for(seq);
            frames.push(frame(seq, p.digest(), p));
        }
        // Duplicates of valid frames (same correct header).
        let ndup = rng.gen_usize(6);
        for _ in 0..ndup {
            let seq = 1 + rng.gen_usize(nvalid as usize) as u64;
            let p = payload_for(seq);
            frames.push(frame(seq, p.digest(), p));
        }
        // Corrupt class 1: checksum header trashed.
        let nbadsum = rng.gen_usize(6);
        for _ in 0..nbadsum {
            let seq = 1 + rng.gen_usize(nvalid as usize + 5) as u64;
            let p = payload_for(seq);
            let bad = p.digest() ^ (rng.next_u64() | 1);
            frames.push(frame(seq, bad, p));
        }
        // Corrupt class 2: payload bit flipped in flight, checksum stale.
        let nbadbit = rng.gen_usize(6);
        for _ in 0..nbadbit {
            let seq = 1 + rng.gen_usize(nvalid as usize + 5) as u64;
            let p = payload_for(seq);
            let good = p.digest();
            let mut flipped = p;
            assert!(flipped.flip_data_bit(rng.gen_usize(16 * 8)), "RmaPut carries data");
            frames.push(frame(seq, good, flipped));
        }
        // Forged NIC-level acks with garbage channel ids (rel-exempt).
        let nack = rng.gen_usize(6);
        for _ in 0..nack {
            frames.push(WireMsg {
                arrival: 0,
                src_proc: 0,
                src_ctx,
                rel: None,
                payload: Payload::RelAck {
                    ack: rng.next_u64() % 64,
                    chan_src_ctx: (rng.next_u64() % 8) as u32,
                    chan_dst_ctx: (rng.next_u64() % 8) as u32,
                },
            });
        }
        rng.shuffle(&mut frames);
        for f in frames {
            dst_ctx.deliver(f);
        }

        // Poll the whole queue through the admission point: must never
        // panic, and must admit exactly seqs 1..=nvalid in order.
        let mut admitted = Vec::new();
        while let Some(m) = rx.poll_ctx(dst_idx) {
            match m.payload {
                Payload::RmaPut { flush_handle, data, .. } => {
                    assert_eq!(data, vec![flush_handle as u8; 16], "admitted frame mangled");
                    admitted.push(flush_handle);
                }
                other => panic!("seed {seed}: decoder leaked a non-data frame: {other:?}"),
            }
        }
        let want: Vec<u64> = (1..=nvalid).collect();
        assert_eq!(admitted, want, "seed {seed}: admission diverged from the seq oracle");
        let s = net.fault_plan().expect("plan installed").counters.snapshot();
        assert_eq!(
            s.rel_corrupt_drops,
            (nbadsum + nbadbit) as u64,
            "seed {seed}: every corrupt frame must be counted"
        );
        assert_eq!(s.rel_dup_drops, ndup as u64, "seed {seed}: every duplicate counted");
    }
}

/// The zero-cost claim, structurally: without a `vcmpi_fault_plan` no
/// reliability state exists, no frame carries a rel header, and no fault
/// counters appear in the measurement map.
#[test]
fn fault_free_runs_carry_no_reliability_state() {
    let r = chaos_storm_free();
    assert_eq!(r.outcome, SimOutcome::Completed);
    assert!(
        !r.measurements.keys().any(|k| k.starts_with("fault_")),
        "fault counters recorded without a fault plan"
    );
}

fn chaos_storm_free() -> RunReport {
    let spec = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Opa,
            nodes: 2,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        MpiConfig::optimized(4),
        1,
    );
    run_cluster(spec, |proc, _t| {
        let world = proc.comm_world();
        let peer = 1 - proc.rank();
        let sreq = proc.isend(&world, peer, 1, &[9u8; 64]);
        let got = proc.recv(&world, Src::Rank(peer), Tag::Value(1));
        assert_eq!(got, vec![9u8; 64]);
        proc.wait(sreq);
        proc.barrier(&world);
    })
}
