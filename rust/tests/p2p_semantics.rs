//! Integration tests: two-sided semantics across the full stack
//! (DES scheduler -> fabric -> vcmpi), under every library configuration.

use vcmpi::fabric::{FabricConfig, Interconnect};
use vcmpi::mpi::{run_cluster, ClusterSpec, MpiConfig, Src, Tag};
use vcmpi::sim::SimOutcome;

fn fabric(interconnect: Interconnect, nodes: usize, ppn: usize) -> FabricConfig {
    FabricConfig { interconnect, nodes, procs_per_node: ppn, max_contexts_per_node: 64 }
}

fn run_ok(
    spec: ClusterSpec,
    body: impl Fn(&std::sync::Arc<vcmpi::mpi::MpiProc>, usize) + Send + Sync + 'static,
) {
    let r = run_cluster(spec, body);
    assert_eq!(r.outcome, SimOutcome::Completed, "cluster run failed: {:?}", r.outcome);
}

fn all_configs() -> Vec<(&'static str, MpiConfig)> {
    vec![
        ("original", MpiConfig::original()),
        ("fg_single", MpiConfig::fg_single_vci()),
        ("optimized4", MpiConfig::optimized(4)),
        ("optimized16", MpiConfig::optimized(16)),
        ("striped8", MpiConfig::striped(8)),
        ("striped_sharded8", MpiConfig::striped_sharded(8)),
    ]
}

#[test]
fn ping_pong_all_configs_both_fabrics() {
    for ic in [Interconnect::Opa, Interconnect::Ib] {
        for (name, cfg) in all_configs() {
            let spec = ClusterSpec::new(fabric(ic, 2, 1), cfg, 1);
            run_ok(spec, move |proc, _t| {
                let world = proc.comm_world();
                let payload = vec![0xAB; 64];
                if proc.rank() == 0 {
                    proc.send(&world, 1, 7, &payload);
                    let back = proc.recv(&world, Src::Rank(1), Tag::Value(8));
                    assert_eq!(back, vec![0xCD; 32], "echo payload ({name})");
                } else {
                    let got = proc.recv(&world, Src::Rank(0), Tag::Value(7));
                    assert_eq!(got, vec![0xAB; 64], "ping payload ({name})");
                    proc.send(&world, 0, 8, &vec![0xCD; 32]);
                }
            });
        }
    }
}

#[test]
fn large_messages_use_rendezvous_and_arrive_intact() {
    // 256 KiB >> rendezvous threshold (16 KiB).
    let spec = ClusterSpec::new(fabric(Interconnect::Ib, 2, 1), MpiConfig::optimized(4), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        let n = 256 * 1024;
        if proc.rank() == 0 {
            let data: Vec<u8> = (0..n).map(|i| (i % 251) as u8).collect();
            proc.send(&world, 1, 1, &data);
        } else {
            let got = proc.recv(&world, Src::Rank(0), Tag::Value(1));
            assert_eq!(got.len(), n);
            assert!(got.iter().enumerate().all(|(i, &b)| b == (i % 251) as u8));
        }
    });
}

#[test]
fn nonovertaking_same_comm_same_rank() {
    // 50 back-to-back sends with the same envelope must be received in
    // program order (MPI's nonovertaking rule).
    let spec = ClusterSpec::new(fabric(Interconnect::Opa, 2, 1), MpiConfig::optimized(8), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        if proc.rank() == 0 {
            for i in 0..50u32 {
                proc.send(&world, 1, 3, &i.to_le_bytes());
            }
        } else {
            for i in 0..50u32 {
                let got = proc.recv(&world, Src::Rank(0), Tag::Value(3));
                assert_eq!(u32::from_le_bytes(got.as_slice().try_into().unwrap()), i);
            }
        }
    });
}

#[test]
fn any_source_receives_from_all() {
    let spec = ClusterSpec::new(fabric(Interconnect::Ib, 4, 1), MpiConfig::optimized(4), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        if proc.rank() == 0 {
            let mut seen = [false; 4];
            for _ in 0..3 {
                let got = proc.recv(&world, Src::Any, Tag::Any);
                let who = got[0] as usize;
                assert!(!seen[who], "duplicate sender {who}");
                seen[who] = true;
            }
            assert!(seen[1] && seen[2] && seen[3]);
        } else {
            proc.send(&world, 0, proc.rank() as i32, &[proc.rank() as u8]);
        }
    });
}

#[test]
fn tags_disambiguate_within_a_comm() {
    let spec = ClusterSpec::new(fabric(Interconnect::Opa, 2, 1), MpiConfig::optimized(4), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        if proc.rank() == 0 {
            proc.send(&world, 1, 10, b"ten");
            proc.send(&world, 1, 20, b"twenty");
        } else {
            // Post in reverse tag order: matching must honor tags.
            let twenty = proc.recv(&world, Src::Rank(0), Tag::Value(20));
            let ten = proc.recv(&world, Src::Rank(0), Tag::Value(10));
            assert_eq!(twenty, b"twenty");
            assert_eq!(ten, b"ten");
        }
    });
}

#[test]
fn ssend_completes_only_after_match() {
    // An Ssend must not complete before the receiver posts. We verify
    // completion ordering via virtual time: the receiver delays its post
    // by 1ms; the sender's ssend return time must be after that.
    let spec = ClusterSpec::new(fabric(Interconnect::Ib, 2, 1), MpiConfig::optimized(4), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        if proc.rank() == 0 {
            proc.ssend(&world, 1, 5, &[1, 2, 3]);
            let t = vcmpi::sim::now();
            assert!(t >= 1_000_000, "ssend returned at {t}ns, before receiver posted");
        } else {
            vcmpi::sim::advance(1_000_000); // compute before posting
            let got = proc.recv(&world, Src::Rank(0), Tag::Value(5));
            assert_eq!(got, vec![1, 2, 3]);
        }
    });
}

#[test]
fn isend_immediate_completion_for_small_messages() {
    // Small standard-mode sends complete at injection: wait() must not
    // require the receiver to have posted anything.
    let spec = ClusterSpec::new(fabric(Interconnect::Ib, 2, 1), MpiConfig::optimized(4), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        if proc.rank() == 0 {
            let reqs: Vec<_> = (0..10).map(|i| proc.isend(&world, 1, 9, &[i])).collect();
            for r in &reqs {
                assert!(matches!(r, vcmpi::mpi::Request::Lightweight { .. }));
            }
            proc.waitall(reqs);
            // Tell the receiver it can start now.
            proc.send(&world, 1, 99, &[]);
        } else {
            proc.recv(&world, Src::Rank(0), Tag::Value(99));
            for i in 0..10u8 {
                let got = proc.recv(&world, Src::Rank(0), Tag::Value(9));
                assert_eq!(got, vec![i]);
            }
        }
    });
}

#[test]
fn multi_threaded_distinct_comms_exchange() {
    // 4 threads per process, each pair on its own duplicated communicator
    // (the paper's par_comm pattern). Thread 0 creates the communicators
    // collectively; a per-process OnceLock hands them to the other threads.
    use std::sync::{Arc, Mutex};
    let spec = ClusterSpec::new(fabric(Interconnect::Ib, 2, 1), MpiConfig::optimized(8), 4);
    let comms: Arc<Mutex<std::collections::HashMap<usize, Vec<vcmpi::mpi::Comm>>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let bars: Arc<Vec<vcmpi::platform::PBarrier>> = Arc::new(
        (0..2).map(|_| vcmpi::platform::PBarrier::new(vcmpi::platform::Backend::Sim, 4)).collect(),
    );
    let c2 = comms.clone();
    run_ok(spec, move |proc, t| {
        if t == 0 {
            let world = proc.comm_world();
            let v: Vec<_> = (0..4).map(|_| proc.comm_dup(&world)).collect();
            c2.lock().unwrap().insert(proc.rank(), v);
        }
        bars[proc.rank()].wait();
        let comm = c2.lock().unwrap().get(&proc.rank()).unwrap()[t].clone();
        let peer = 1 - proc.rank();
        let msg = [t as u8; 16];
        let sreq = proc.isend(&comm, peer, t as i32, &msg);
        let rreq = proc.irecv(&comm, Src::Rank(peer), Tag::Value(t as i32));
        let got = proc.wait(rreq).unwrap();
        proc.wait(sreq);
        assert_eq!(got, vec![t as u8; 16]);
        bars[proc.rank()].wait();
    });
}

#[test]
fn native_backend_ping_pong() {
    let mut spec = ClusterSpec::new(fabric(Interconnect::Ib, 2, 1), MpiConfig::optimized(4), 1);
    spec.backend = vcmpi::platform::Backend::Native;
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        if proc.rank() == 0 {
            proc.send(&world, 1, 7, b"native");
            let got = proc.recv(&world, Src::Rank(1), Tag::Value(8));
            assert_eq!(got, b"pong");
        } else {
            let got = proc.recv(&world, Src::Rank(0), Tag::Value(7));
            assert_eq!(got, b"native");
            proc.send(&world, 0, 8, b"pong");
        }
    });
}

#[test]
fn mpi4_hints_spread_one_comm_and_stay_correct() {
    // Paper §7: with mpi_assert_no_any_source + no_any_tag, tag-level
    // parallelism within ONE communicator maps to multiple VCIs — and
    // ordered delivery per (src, tag) stream is preserved.
    let mut cfg = MpiConfig::optimized(8);
    cfg.hints.no_any_source = true;
    cfg.hints.no_any_tag = true;
    let spec = ClusterSpec::new(fabric(Interconnect::Ib, 2, 1), cfg, 4);
    run_ok(spec, |proc, t| {
        let world = proc.comm_world(); // the ONE communicator
        let peer = 1 - proc.rank();
        for i in 0..40u32 {
            let sreq = proc.isend(&world, peer, t as i32, &i.to_le_bytes());
            let got = proc.recv(&world, Src::Rank(peer), Tag::Value(t as i32));
            assert_eq!(u32::from_le_bytes(got.as_slice().try_into().unwrap()), i);
            proc.wait(sreq);
        }
    });
}

#[test]
fn mpi4_hints_make_wildcards_erroneous() {
    let mut cfg = MpiConfig::optimized(4);
    cfg.hints.no_any_source = true;
    cfg.hints.no_any_tag = true;
    let spec = ClusterSpec::new(fabric(Interconnect::Ib, 2, 1), cfg, 1);
    let r = vcmpi::mpi::run_cluster(spec, |proc, _t| {
        let world = proc.comm_world();
        if proc.rank() == 0 {
            // Erroneous: wildcard under the asserted hints.
            let _ = proc.irecv(&world, Src::Any, Tag::Any);
        }
    });
    assert!(
        matches!(r.outcome, SimOutcome::Panicked(ref m) if m.contains("wildcard")),
        "expected the wildcard to be rejected, got {:?}",
        r.outcome
    );
}

#[test]
fn mpi4_hints_scale_a_single_communicator() {
    // The §7 payoff: ser_comm (one communicator) scales once hints allow
    // envelope spreading.
    use vcmpi::bench::{message_rate, Mode, RateParams};
    let run = |hinted: bool| {
        let mut cfg = MpiConfig::optimized(9);
        cfg.hints.no_any_source = hinted;
        cfg.hints.no_any_tag = hinted;
        message_rate(RateParams {
            mode: Mode::SerCommVcis,
            threads: 8,
            msgs_per_core: 512,
            cfg_override: Some(cfg),
            ..Default::default()
        })
    };
    let off = run(false);
    let on = run(true);
    assert!(
        on > 4.0 * off,
        "hints should unlock single-comm scaling: on={on:.0} off={off:.0}"
    );
}
