//! Native-backend (real OS threads) correctness: the same library code
//! under genuine concurrency, plus stress tests for the host-safety of
//! the shared structures.

use std::sync::{Arc, Mutex};

use vcmpi::fabric::{AccOp, FabricConfig, Interconnect};
use vcmpi::mpi::{run_cluster, ClusterSpec, MpiConfig, Src, Tag};
use vcmpi::platform::Backend;
use vcmpi::sim::SimOutcome;

fn native_spec(ic: Interconnect, nodes: usize, tpp: usize, cfg: MpiConfig) -> ClusterSpec {
    let mut spec = ClusterSpec::new(
        FabricConfig { interconnect: ic, nodes, procs_per_node: 1, max_contexts_per_node: 64 },
        cfg,
        tpp,
    );
    spec.backend = Backend::Native;
    spec
}

#[test]
fn native_multithreaded_streams() {
    // 4 real threads per process exchanging on dedicated comms.
    let spec = native_spec(Interconnect::Ib, 2, 4, MpiConfig::optimized(8));
    let comms: Arc<Mutex<std::collections::HashMap<usize, Vec<vcmpi::mpi::Comm>>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let bars: Arc<Vec<vcmpi::platform::PBarrier>> = Arc::new(
        (0..2).map(|_| vcmpi::platform::PBarrier::new(Backend::Native, 4)).collect(),
    );
    let c2 = comms.clone();
    let r = run_cluster(spec, move |proc, t| {
        if t == 0 {
            let world = proc.comm_world();
            let v: Vec<_> = (0..4).map(|_| proc.comm_dup(&world)).collect();
            c2.lock().unwrap().insert(proc.rank(), v);
        }
        bars[proc.rank()].wait();
        let comm = c2.lock().unwrap().get(&proc.rank()).unwrap()[t].clone();
        let peer = 1 - proc.rank();
        for i in 0..200u32 {
            let sreq = proc.isend(&comm, peer, t as i32, &i.to_le_bytes());
            let got = proc.recv(&comm, Src::Rank(peer), Tag::Value(t as i32));
            assert_eq!(u32::from_le_bytes(got.as_slice().try_into().unwrap()), i);
            proc.wait(sreq);
        }
        bars[proc.rank()].wait();
    });
    assert_eq!(r.outcome, SimOutcome::Completed, "{:?}", r.outcome);
}

#[test]
fn native_global_cs_serializes_correctly() {
    // The Global critical section must stay correct under real threads.
    let spec = native_spec(Interconnect::Ib, 2, 4, MpiConfig::original());
    let r = run_cluster(spec, move |proc, t| {
        let world = proc.comm_world();
        let peer = 1 - proc.rank();
        for i in 0..50u32 {
            let sreq = proc.isend(&world, peer, t as i32, &i.to_le_bytes());
            let got = proc.recv(&world, Src::Rank(peer), Tag::Value(t as i32));
            assert_eq!(u32::from_le_bytes(got.as_slice().try_into().unwrap()), i);
            proc.wait(sreq);
        }
    });
    assert_eq!(r.outcome, SimOutcome::Completed, "{:?}", r.outcome);
}

#[test]
fn native_rma_and_fetch_op() {
    let spec = native_spec(Interconnect::Opa, 2, 2, MpiConfig::optimized(4));
    let bars: Arc<Vec<vcmpi::platform::PBarrier>> = Arc::new(
        (0..2).map(|_| vcmpi::platform::PBarrier::new(Backend::Native, 2)).collect(),
    );
    let wins: Arc<Mutex<std::collections::HashMap<usize, Arc<vcmpi::mpi::Window>>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let w2 = wins.clone();
    let r = run_cluster(spec, move |proc, t| {
        let world = proc.comm_world();
        let me = proc.rank();
        if t == 0 {
            let win = proc.win_create(&world, 1024);
            w2.lock().unwrap().insert(me, win);
        }
        bars[me].wait();
        let win = w2.lock().unwrap().get(&me).unwrap().clone();
        // Both threads of both procs bump a counter on rank 0: 4 x 25.
        for _ in 0..25 {
            proc.fetch_and_op(&win, 0, 0, &1u64.to_le_bytes(), AccOp::SumU64);
        }
        bars[me].wait();
        if t == 0 {
            proc.barrier(&world);
        }
        bars[me].wait();
        if me == 0 && t == 0 {
            let v = u64::from_le_bytes(win.read_local(0, 8).try_into().unwrap());
            assert_eq!(v, 100);
        }
        bars[me].wait();
        if t == 0 {
            let win = { w2.lock().unwrap().remove(&me).unwrap() };
            proc.win_free(&world, win);
        }
    });
    assert_eq!(r.outcome, SimOutcome::Completed, "{:?}", r.outcome);
}

#[test]
fn native_collectives() {
    let spec = native_spec(Interconnect::Ib, 4, 1, MpiConfig::optimized(4));
    let r = run_cluster(spec, move |proc, _t| {
        let world = proc.comm_world();
        let mut xs: Vec<f32> = (0..257).map(|i| (proc.rank() + 1) as f32 * i as f32).collect();
        proc.allreduce_f32(&world, &mut xs);
        for (i, &v) in xs.iter().enumerate() {
            let want = 10.0 * i as f32;
            assert!((v - want).abs() <= want.abs() * 1e-5 + 1e-3);
        }
        let all = proc.allgather_bytes(&world, &[proc.rank() as u8]);
        assert_eq!(all.len(), 4);
        for (r, b) in all.iter().enumerate() {
            assert_eq!(b, &vec![r as u8]);
        }
    });
    assert_eq!(r.outcome, SimOutcome::Completed, "{:?}", r.outcome);
}

#[test]
fn native_endpoints() {
    let spec = native_spec(Interconnect::Ib, 2, 2, MpiConfig::optimized(6));
    let eps: Arc<Mutex<std::collections::HashMap<usize, vcmpi::mpi::Comm>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let bars: Arc<Vec<vcmpi::platform::PBarrier>> = Arc::new(
        (0..2).map(|_| vcmpi::platform::PBarrier::new(Backend::Native, 2)).collect(),
    );
    let e2 = eps.clone();
    let r = run_cluster(spec, move |proc, t| {
        if t == 0 {
            let world = proc.comm_world();
            let ep = proc.create_endpoints(&world, 2);
            e2.lock().unwrap().insert(proc.rank(), ep);
        }
        bars[proc.rank()].wait();
        let ep = e2.lock().unwrap().get(&proc.rank()).unwrap().clone();
        let peer_proc = 1 - proc.rank();
        let to = proc.endpoint_rank(&ep, peer_proc, t);
        let sreq = proc.isend_ep(&ep, Some(t), to, 3, &[t as u8; 4], false);
        let got = {
            let rreq = proc.irecv_ep(&ep, Some(t), Src::Rank(to), Tag::Value(3));
            proc.wait(rreq).unwrap()
        };
        proc.wait(sreq);
        assert_eq!(got, vec![t as u8; 4]);
        bars[proc.rank()].wait();
    });
    assert_eq!(r.outcome, SimOutcome::Completed, "{:?}", r.outcome);
}
