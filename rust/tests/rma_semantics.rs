//! RMA semantics across both interconnect personalities: put/get/
//! accumulate/fetch-and-op correctness, flush completion, atomicity —
//! plus the per-window policy layer: striped windows (info-keyed
//! per-message fan-out with counted-ack flush) vs ordered windows
//! (program order, pinned lanes).

use std::sync::Arc;

use vcmpi::fabric::{AccOp, FabricConfig, Interconnect};
use vcmpi::mpi::{run_cluster, ClusterSpec, Info, LockKind, MpiConfig, MpiProc};
use vcmpi::sim::SimOutcome;

fn fabric(interconnect: Interconnect, nodes: usize) -> FabricConfig {
    FabricConfig { interconnect, nodes, procs_per_node: 1, max_contexts_per_node: 64 }
}

fn run_ok(spec: ClusterSpec, body: impl Fn(&Arc<MpiProc>, usize) + Send + Sync + 'static) {
    let r = run_cluster(spec, body);
    assert_eq!(r.outcome, SimOutcome::Completed, "cluster run failed: {:?}", r.outcome);
}

#[test]
fn put_then_flush_is_visible_both_fabrics() {
    for ic in [Interconnect::Ib, Interconnect::Opa] {
        let spec = ClusterSpec::new(fabric(ic, 2), MpiConfig::optimized(4), 1);
        run_ok(spec, move |proc, _t| {
            let world = proc.comm_world();
            let win = proc.win_create(&world, 256);
            if proc.rank() == 0 {
                proc.put(&win, 1, 16, &[7u8; 32]);
                proc.win_flush(&win);
                proc.send(&world, 1, 1, &[]); // "put is flushed"
            } else {
                proc.recv(&world, vcmpi::mpi::Src::Rank(0), vcmpi::mpi::Tag::Value(1));
                assert_eq!(win.read_local(16, 32), vec![7u8; 32], "{ic:?}");
            }
            proc.win_free(&world, win);
        });
    }
}

#[test]
fn get_round_trip_both_fabrics() {
    for ic in [Interconnect::Ib, Interconnect::Opa] {
        let spec = ClusterSpec::new(fabric(ic, 2), MpiConfig::optimized(4), 1);
        run_ok(spec, move |proc, _t| {
            let world = proc.comm_world();
            let win = proc.win_create(&world, 128);
            if proc.rank() == 1 {
                win.write_local(0, &[0xEE; 64]);
            }
            proc.barrier(&world);
            if proc.rank() == 0 {
                let h = proc.get(&win, 1, 0, 64);
                proc.win_flush(&win);
                assert_eq!(proc.get_data(&win, h), vec![0xEE; 64], "{ic:?}");
            }
            proc.barrier(&world);
            proc.win_free(&world, win);
        });
    }
}

#[test]
fn accumulate_sums_from_many_ranks() {
    // 4 ranks each accumulate 1.5 into the same f64 cell on rank 0, twice.
    let spec = ClusterSpec::new(fabric(Interconnect::Opa, 4), MpiConfig::optimized(4), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        let win = proc.win_create(&world, 64);
        for _ in 0..2 {
            proc.accumulate(&win, 0, 8, &1.5f64.to_le_bytes(), AccOp::SumF64);
        }
        proc.win_flush(&win);
        proc.barrier(&world);
        if proc.rank() == 0 {
            let bytes = win.read_local(8, 8);
            let v = f64::from_le_bytes(bytes.try_into().unwrap());
            assert!((v - 12.0).abs() < 1e-12, "4 ranks x 2 x 1.5 = 12, got {v}");
        }
        proc.win_free(&world, win);
    });
}

#[test]
fn accumulate_program_order_preserved_by_default() {
    // Two ordered Replace accumulates from the same origin to the same
    // location: the later one must win (default accumulate_ordering).
    for ic in [Interconnect::Ib, Interconnect::Opa] {
        let spec = ClusterSpec::new(fabric(ic, 2), MpiConfig::optimized(4), 1);
        run_ok(spec, move |proc, _t| {
            let world = proc.comm_world();
            let win = proc.win_create(&world, 64);
            if proc.rank() == 0 {
                proc.accumulate(&win, 1, 0, &[1u8; 8], AccOp::Replace);
                proc.accumulate(&win, 1, 0, &[2u8; 8], AccOp::Replace);
                proc.win_flush(&win);
                proc.send(&world, 1, 1, &[]);
            } else {
                proc.recv(&world, vcmpi::mpi::Src::Rank(0), vcmpi::mpi::Tag::Value(1));
                assert_eq!(win.read_local(0, 8), vec![2u8; 8], "{ic:?}: program order");
            }
            proc.win_free(&world, win);
        });
    }
}

#[test]
fn fetch_and_op_is_an_atomic_counter() {
    // All 4 ranks hammer a shared u64 counter with fetch-and-add(1) x 8:
    // every rank must see a unique sequence of values, and the final count
    // must be 32.
    let spec = ClusterSpec::new(fabric(Interconnect::Opa, 4), MpiConfig::optimized(4), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        let win = proc.win_create(&world, 64);
        let mut fetched = Vec::new();
        for _ in 0..8 {
            let prev = proc.fetch_and_op(&win, 0, 0, &1u64.to_le_bytes(), AccOp::SumU64);
            fetched.push(u64::from_le_bytes(prev.try_into().unwrap()));
        }
        // Monotonically increasing per rank (no duplicated grants).
        for w in fetched.windows(2) {
            assert!(w[1] > w[0], "fetch_and_op must grant increasing values");
        }
        proc.barrier(&world);
        if proc.rank() == 0 {
            let v = u64::from_le_bytes(win.read_local(0, 8).try_into().unwrap());
            assert_eq!(v, 32);
        }
        proc.win_free(&world, win);
    });
}

#[test]
fn multiple_windows_are_independent_streams() {
    // Threads on distinct windows run concurrent RMA without interference.
    let spec = ClusterSpec::new(fabric(Interconnect::Ib, 2), MpiConfig::optimized(8), 4);
    use std::sync::Mutex;
    let wins: Arc<Mutex<std::collections::HashMap<usize, Vec<Arc<vcmpi::mpi::Window>>>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let bars: Arc<Vec<vcmpi::platform::PBarrier>> = Arc::new(
        (0..2).map(|_| vcmpi::platform::PBarrier::new(vcmpi::platform::Backend::Sim, 4)).collect(),
    );
    let w2 = wins.clone();
    run_ok(spec, move |proc, t| {
        if t == 0 {
            let world = proc.comm_world();
            let v: Vec<_> = (0..4).map(|_| proc.win_create(&world, 1024)).collect();
            w2.lock().unwrap().insert(proc.rank(), v);
        }
        bars[proc.rank()].wait();
        let win = w2.lock().unwrap().get(&proc.rank()).unwrap()[t].clone();
        let peer = 1 - proc.rank();
        let pattern = vec![t as u8 + 1; 128];
        proc.put(&win, peer, t * 128, &pattern);
        proc.win_flush(&win);
        bars[proc.rank()].wait();
        // Peer wrote into OUR window at the same offset with their pattern.
        assert_eq!(win.read_local(t * 128, 128), vec![t as u8 + 1; 128]);
        bars[proc.rank()].wait();
    });
}

/// The striped-window info keys used across the policy tests.
fn striped_info() -> Info {
    Info::new()
        .with("accumulate_ordering", "none")
        .with("vcmpi_striping", "rr")
        .with("vcmpi_rx_doorbell", "true")
}

#[test]
fn striped_window_flush_under_concurrent_multi_target_accumulates() {
    // Three origin threads on rank 0 stripe accumulates at TWO targets
    // concurrently (each thread owns one 8-byte cell per target), each
    // thread flushing its own ops: per-thread watermarks against the
    // shared per-(window, target, lane) counters must complete exactly —
    // no lost acks, no cross-thread confusion — and the sums must land.
    const REPS: u64 = 16;
    let spec = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Opa,
            nodes: 3,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        MpiConfig::optimized(6),
        3,
    );
    use std::collections::HashMap;
    use std::sync::Mutex;
    let wins: Arc<Mutex<HashMap<usize, Arc<vcmpi::mpi::Window>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let bars: Arc<Vec<vcmpi::platform::PBarrier>> = Arc::new(
        (0..3).map(|_| vcmpi::platform::PBarrier::new(vcmpi::platform::Backend::Sim, 3)).collect(),
    );
    run_ok(spec, move |proc, t| {
        let world = proc.comm_world();
        let me = proc.rank();
        if t == 0 {
            let win = proc.win_create_with_info(&world, 64, &striped_info());
            wins.lock().unwrap().insert(me, win);
        }
        bars[me].wait();
        let win = wins.lock().unwrap().get(&me).unwrap().clone();
        if me == 0 {
            for _ in 0..REPS {
                for target in [1usize, 2] {
                    proc.accumulate(&win, target, t * 8, &1u64.to_le_bytes(), AccOp::SumU64);
                }
            }
            proc.win_flush(&win);
        }
        bars[me].wait();
        if t == 0 {
            proc.barrier(&world);
        }
        bars[me].wait();
        if me != 0 && t == 0 {
            for cell in 0..3 {
                let v = u64::from_le_bytes(win.read_local(cell * 8, 8).try_into().unwrap());
                assert_eq!(v, REPS, "rank {me} cell {cell}: striped accumulates lost/duplicated");
            }
        }
        bars[me].wait();
        if t == 0 {
            let win = { wins.lock().unwrap().remove(&me) };
            proc.win_free(&world, win.unwrap());
        }
    });
}

#[test]
fn striped_window_gets_fan_out_and_flush_counts_per_lane() {
    // The striped-MPI_Get mirror of the striped-put watermark test: one
    // origin thread issues a batch of gets on a striped window; each
    // reply carries the issuing lane (like RmaAckCount), counts toward
    // that lane's per-(window, target) watermark, and the data must land
    // exactly — spread across multiple lanes, not funneled through one.
    const SLOTS: usize = 16;
    let spec = ClusterSpec::new(fabric(Interconnect::Opa, 2), MpiConfig::optimized(6), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        let win = proc.win_create_with_info(&world, SLOTS * 8, &striped_info());
        assert!(win.policy.stripes_gets());
        if proc.rank() == 1 {
            for i in 0..SLOTS {
                win.write_local(i * 8, &(0xA0A0_0000_u64 + i as u64).to_le_bytes());
            }
        }
        proc.barrier(&world);
        if proc.rank() == 0 {
            let handles: Vec<_> = (0..SLOTS).map(|i| proc.get(&win, 1, i * 8, 8)).collect();
            proc.win_flush(&win);
            let lanes: std::collections::HashSet<usize> =
                handles.iter().map(|h| h.1).collect();
            assert!(
                lanes.len() > 1,
                "striped gets must fan out across lanes, got only {lanes:?}"
            );
            for (i, h) in handles.into_iter().enumerate() {
                assert_eq!(
                    proc.get_data(&win, h),
                    (0xA0A0_0000_u64 + i as u64).to_le_bytes().to_vec(),
                    "slot {i}"
                );
            }
            assert_eq!(proc.stale_ctrl_drop_count(), 0);
        }
        proc.barrier(&world);
        proc.win_free(&world, win);
    });
}

#[test]
fn striped_window_without_relaxed_ordering_keeps_accumulate_program_order() {
    // Decision table, middle row: `vcmpi_striping` alone stripes PUTS
    // (MPI imposes no inter-put order) but accumulates stay on the home
    // VCI in program order.
    let spec = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Opa,
            nodes: 2,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        MpiConfig::optimized(5),
        1,
    );
    run_ok(spec, move |proc, _t| {
        let world = proc.comm_world();
        let info = Info::new().with("vcmpi_striping", "hash");
        let win = proc.win_create_with_info(&world, 512, &info);
        assert!(win.policy.stripes_puts());
        assert!(!win.policy.stripes_accumulates());
        if proc.rank() == 0 {
            // Striped puts to distinct slots...
            for slot in 0..8usize {
                proc.put(&win, 1, 64 + slot * 32, &[slot as u8 + 1; 32]);
            }
            // ...and ordered Replace accumulates to one cell.
            proc.accumulate(&win, 1, 0, &[1u8; 8], AccOp::Replace);
            proc.accumulate(&win, 1, 0, &[2u8; 8], AccOp::Replace);
            proc.win_flush(&win);
            proc.send(&world, 1, 1, &[]);
        } else {
            proc.recv(&world, vcmpi::mpi::Src::Rank(0), vcmpi::mpi::Tag::Value(1));
            assert_eq!(win.read_local(0, 8), vec![2u8; 8], "accumulate program order");
            for slot in 0..8usize {
                assert_eq!(
                    win.read_local(64 + slot * 32, 32),
                    vec![slot as u8 + 1; 32],
                    "striped put slot {slot}"
                );
            }
        }
        proc.win_free(&world, win);
    });
}

#[test]
fn ordered_window_pins_its_lane_striped_window_does_not() {
    // Pin interaction: an ordered window protects its home VCI from
    // striped bulk (two-sided OR one-sided), exactly like an ordered
    // communicator; a striped window leaves its lane in the stripe set;
    // win_free releases the pin.
    let spec = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Opa,
            nodes: 1,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        MpiConfig::optimized(4),
        1,
    );
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        let ordered = proc.win_create(&world, 64);
        assert_ne!(ordered.vci, 0, "pool assigns a non-fallback lane");
        assert!(proc.stripe_lane_pinned(ordered.vci), "ordered window pins its lane");
        let striped = proc.win_create_with_info(&world, 64, &striped_info());
        assert!(
            !proc.stripe_lane_pinned(striped.vci),
            "striped window's home lane stays a stripe lane"
        );
        let freed_lane = ordered.vci;
        proc.win_free(&world, ordered);
        assert!(!proc.stripe_lane_pinned(freed_lane), "win_free unpins");
        proc.win_free(&world, striped);
    });
}

#[test]
fn ordered_window_and_striped_comm_share_the_pool() {
    // Mixed-policy pool: a latency-ordered window (pinned lane,
    // flush-handle completion) and an info-keyed striped communicator's
    // p2p storm coexist in one process. The window must keep accumulate
    // program order and the striped traffic must stay off its lane (by
    // construction of the pin — asserted via the pin itself and a clean
    // policy-mismatch count).
    let spec = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Opa,
            nodes: 2,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        MpiConfig::optimized(5),
        2,
    );
    use std::collections::HashMap;
    use std::sync::Mutex;
    type Shared = (vcmpi::mpi::Comm, Arc<vcmpi::mpi::Window>);
    let shared: Arc<Mutex<HashMap<usize, Shared>>> = Arc::new(Mutex::new(HashMap::new()));
    let bars: Arc<Vec<vcmpi::platform::PBarrier>> = Arc::new(
        (0..2).map(|_| vcmpi::platform::PBarrier::new(vcmpi::platform::Backend::Sim, 2)).collect(),
    );
    run_ok(spec, move |proc, t| {
        let world = proc.comm_world();
        let me = proc.rank();
        if t == 0 {
            // Symmetric creation order: hot comm first, then the window.
            let hot = proc.comm_dup_with_info(
                &world,
                &Info::new()
                    .with("vcmpi_striping", "rr")
                    .with("vcmpi_match_shards", "4")
                    .with("vcmpi_rx_doorbell", "true"),
            );
            let win = proc.win_create(&world, 64);
            assert!(proc.stripe_lane_pinned(win.vci));
            shared.lock().unwrap().insert(me, (hot, win));
        }
        bars[me].wait();
        let (hot, win) = shared.lock().unwrap().get(&me).unwrap().clone();
        if t == 1 {
            // Striped p2p storm on the hot comm, concurrent with the RMA.
            if me == 0 {
                let reqs: Vec<_> =
                    (0..64).map(|_| proc.isend(&hot, 1, 7, &[0u8; 16])).collect();
                proc.waitall(reqs);
            } else {
                let reqs: Vec<_> = (0..64)
                    .map(|_| {
                        proc.irecv(&hot, vcmpi::mpi::Src::Rank(0), vcmpi::mpi::Tag::Value(7))
                    })
                    .collect();
                proc.waitall(reqs);
            }
        } else if me == 0 {
            proc.accumulate(&win, 1, 0, &[1u8; 8], AccOp::Replace);
            proc.accumulate(&win, 1, 0, &[2u8; 8], AccOp::Replace);
            proc.win_flush(&win);
            proc.send(&world, 1, 1, &[]);
        } else {
            proc.recv(&world, vcmpi::mpi::Src::Rank(0), vcmpi::mpi::Tag::Value(1));
            assert_eq!(win.read_local(0, 8), vec![2u8; 8], "program order beside striped p2p");
        }
        bars[me].wait();
        if t == 0 {
            proc.barrier(&world);
            assert_eq!(proc.policy_mismatch_count(), 0, "wire contract held");
            if me == 1 {
                assert!(proc.has_match_engine(hot.id), "hot comm sharded on the receiver");
            }
            let (hot, win) = { shared.lock().unwrap().remove(&me).unwrap() };
            proc.win_free(&world, win);
            proc.comm_free(hot);
        }
        bars[me].wait();
    });
}

#[test]
fn opa_put_needs_target_progress_ib_does_not() {
    // Measure flush latency on both fabrics while the target is busy
    // (no polling for 2ms). IB's hardware RMA should flush in ~wire time;
    // OPA's software RMA must wait for the target's service thread.
    let mut times = std::collections::HashMap::new();
    for ic in [Interconnect::Ib, Interconnect::Opa] {
        let spec = ClusterSpec::new(fabric(ic, 2), MpiConfig::optimized(4), 1);
        let r = run_cluster(spec, move |proc, _t| {
            let world = proc.comm_world();
            let win = proc.win_create(&world, 4096);
            if proc.rank() == 0 {
                let t0 = vcmpi::sim::now();
                proc.put(&win, 1, 0, &[1u8; 2048]);
                proc.win_flush(&win);
                vcmpi::mpi::world::record("flush_ns", (vcmpi::sim::now() - t0) as f64);
            } else {
                // Busy target: no MPI calls for 2ms.
                vcmpi::sim::advance(2_000_000);
            }
            proc.barrier(&world);
            proc.win_free(&world, win);
        });
        assert_eq!(r.outcome, SimOutcome::Completed);
        times.insert(ic, r.measurements["flush_ns"]);
    }
    let ib = times[&Interconnect::Ib];
    let opa = times[&Interconnect::Opa];
    assert!(
        ib < 100_000.0,
        "IB hardware put should flush in ~wire time, took {ib}ns"
    );
    assert!(
        opa > 5.0 * ib,
        "OPA software put should be much slower than IB with a busy target: opa={opa} ib={ib}"
    );
}

// ---- passive-target lock epochs ----

#[test]
fn shared_epoch_put_get_completes_at_unlock() {
    // win_unlock must complete the epoch's ops to that target: the put is
    // visible at the target and the get's data is retrievable, with no
    // explicit flush anywhere. Both interconnect personalities.
    for ic in [Interconnect::Ib, Interconnect::Opa] {
        let spec = ClusterSpec::new(fabric(ic, 2), MpiConfig::optimized(4), 1);
        run_ok(spec, move |proc, _t| {
            let world = proc.comm_world();
            let win = proc.win_create(&world, 256);
            if proc.rank() == 1 {
                win.write_local(64, &[0xAB; 32]);
            }
            proc.barrier(&world);
            if proc.rank() == 0 {
                proc.win_lock(&win, LockKind::Shared, 1);
                proc.put(&win, 1, 0, &[5u8; 32]);
                let h = proc.get(&win, 1, 64, 32);
                proc.win_unlock(&win, 1);
                assert_eq!(proc.get_data(&win, h), vec![0xAB; 32], "{ic:?}: get at unlock");
                proc.send(&world, 1, 7, &[]);
            } else {
                proc.recv(&world, vcmpi::mpi::Src::Rank(0), vcmpi::mpi::Tag::Value(7));
                assert_eq!(win.read_local(0, 32), vec![5u8; 32], "{ic:?}: put at unlock");
            }
            proc.win_free(&world, win);
        });
    }
}

#[test]
fn exclusive_epoch_round_trip_both_fabrics() {
    // Exclusive acquisition paths (OPA wire queue / IB CAS loop) both
    // grant an uncontended lock and release it cleanly.
    for ic in [Interconnect::Ib, Interconnect::Opa] {
        let spec = ClusterSpec::new(fabric(ic, 2), MpiConfig::optimized(4), 1);
        run_ok(spec, move |proc, _t| {
            let world = proc.comm_world();
            let win = proc.win_create(&world, 64);
            if proc.rank() == 0 {
                proc.win_lock(&win, LockKind::Exclusive, 1);
                proc.put(&win, 1, 0, &[9u8; 8]);
                proc.win_unlock(&win, 1);
                proc.send(&world, 1, 7, &[]);
            } else {
                proc.recv(&world, vcmpi::mpi::Src::Rank(0), vcmpi::mpi::Tag::Value(7));
                assert_eq!(win.read_local(0, 8), vec![9u8; 8], "{ic:?}");
            }
            proc.win_free(&world, win);
        });
    }
}

#[test]
fn no_locks_elides_the_wire_protocol() {
    // mpi_assert_no_locks must be load-bearing: the same lock/unlock
    // program text pays zero protocol acquisitions on the asserted window
    // and real ones on the default window — proven by the counters.
    for ic in [Interconnect::Ib, Interconnect::Opa] {
        for elide in [false, true] {
            let spec = ClusterSpec::new(fabric(ic, 2), MpiConfig::optimized(4), 1);
            run_ok(spec, move |proc, _t| {
                let world = proc.comm_world();
                let info = if elide {
                    Info::new().with("mpi_assert_no_locks", "true")
                } else {
                    Info::new()
                };
                let win = proc.win_create_with_info(&world, 64, &info);
                if proc.rank() == 0 {
                    proc.win_lock(&win, LockKind::Shared, 1);
                    proc.put(&win, 1, 0, &[3u8; 8]);
                    proc.win_unlock(&win, 1);
                    proc.send(&world, 1, 7, &[]);
                    if elide {
                        assert!(proc.lock_elision_count() > 0, "{ic:?}: elision fired");
                        assert_eq!(proc.lock_wire_req_count(), 0, "{ic:?}: zero protocol");
                    } else {
                        assert_eq!(proc.lock_elision_count(), 0, "{ic:?}");
                        assert!(proc.lock_wire_req_count() > 0, "{ic:?}: real protocol");
                    }
                } else {
                    proc.recv(&world, vcmpi::mpi::Src::Rank(0), vcmpi::mpi::Tag::Value(7));
                    // Completion semantics survive the elision.
                    assert_eq!(win.read_local(0, 8), vec![3u8; 8], "{ic:?} elide={elide}");
                }
                proc.win_free(&world, win);
            });
        }
    }
}

#[test]
fn flush_local_then_unlock_still_completes_remotely() {
    // flush_local waits local completion only (payloads are captured at
    // injection here, so it is a charged bookkeeping no-op); the unlock
    // must still complete the ops remotely.
    for ic in [Interconnect::Ib, Interconnect::Opa] {
        let spec = ClusterSpec::new(fabric(ic, 2), MpiConfig::optimized(4), 1);
        run_ok(spec, move |proc, _t| {
            let world = proc.comm_world();
            let win = proc.win_create(&world, 64);
            if proc.rank() == 0 {
                proc.win_lock(&win, LockKind::Shared, 1);
                proc.put(&win, 1, 0, &[4u8; 16]);
                proc.win_flush_local(&win);
                proc.win_unlock(&win, 1);
                proc.send(&world, 1, 7, &[]);
            } else {
                proc.recv(&world, vcmpi::mpi::Src::Rank(0), vcmpi::mpi::Tag::Value(7));
                assert_eq!(win.read_local(0, 16), vec![4u8; 16], "{ic:?}");
            }
            proc.win_free(&world, win);
        });
    }
}

#[test]
fn lock_all_composes_with_striped_accumulates() {
    // lock_all/unlock_all over a striped relaxed-ordering window: the
    // counted-ack completion machinery must serve the unlock's flush, and
    // every rank's contributions must land.
    let spec = ClusterSpec::new(fabric(Interconnect::Opa, 3), MpiConfig::optimized(4), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        let info = Info::new()
            .with("accumulate_ordering", "none")
            .with("vcmpi_striping", "rr")
            .with("vcmpi_rx_doorbell", "true");
        let win = proc.win_create_with_info(&world, 64, &info);
        let n = proc.nprocs();
        proc.win_lock_all(&win);
        for target in 0..n {
            for _ in 0..4 {
                proc.accumulate(&win, target, 0, &1u64.to_le_bytes(), AccOp::SumU64);
            }
        }
        proc.win_unlock_all(&win);
        proc.barrier(&world);
        let got = u64::from_le_bytes(win.read_local(0, 8).try_into().unwrap());
        assert_eq!(got, (n * 4) as u64, "every rank's striped contributions landed");
        assert_eq!(proc.policy_mismatch_count(), 0);
        proc.win_free(&world, win);
    });
}

#[test]
fn win_free_with_open_epoch_panics() {
    // The freed-comm-style tripwire: freeing a window with a lock epoch
    // still open is erroneous and must fail loudly, not hang or leak.
    let spec = ClusterSpec::new(fabric(Interconnect::Opa, 1), MpiConfig::optimized(4), 1);
    let r = run_cluster(spec, |proc, _t| {
        let world = proc.comm_world();
        let info = Info::new().with("mpi_assert_no_locks", "true");
        let win = proc.win_create_with_info(&world, 64, &info);
        proc.win_lock(&win, LockKind::Shared, 0);
        proc.win_free(&world, win); // erroneous: epoch still open
    });
    assert!(
        matches!(r.outcome, SimOutcome::Panicked(ref m) if m.contains("passive-target epoch")),
        "expected the open-epoch tripwire, got {:?}",
        r.outcome
    );
}

#[test]
fn second_lock_to_same_target_panics() {
    // MPI allows at most one lock epoch per (window, target) per process.
    let spec = ClusterSpec::new(fabric(Interconnect::Opa, 1), MpiConfig::optimized(4), 1);
    let r = run_cluster(spec, |proc, _t| {
        let world = proc.comm_world();
        let info = Info::new().with("mpi_assert_no_locks", "true");
        let win = proc.win_create_with_info(&world, 64, &info);
        proc.win_lock(&win, LockKind::Shared, 0);
        proc.win_lock(&win, LockKind::Shared, 0); // erroneous
    });
    assert!(
        matches!(r.outcome, SimOutcome::Panicked(ref m) if m.contains("epoch already open")),
        "expected the double-lock tripwire, got {:?}",
        r.outcome
    );
}
