//! RMA semantics across both interconnect personalities: put/get/
//! accumulate/fetch-and-op correctness, flush completion, atomicity.

use std::sync::Arc;

use vcmpi::fabric::{AccOp, FabricConfig, Interconnect};
use vcmpi::mpi::{run_cluster, ClusterSpec, MpiConfig, MpiProc};
use vcmpi::sim::SimOutcome;

fn fabric(interconnect: Interconnect, nodes: usize) -> FabricConfig {
    FabricConfig { interconnect, nodes, procs_per_node: 1, max_contexts_per_node: 64 }
}

fn run_ok(spec: ClusterSpec, body: impl Fn(&Arc<MpiProc>, usize) + Send + Sync + 'static) {
    let r = run_cluster(spec, body);
    assert_eq!(r.outcome, SimOutcome::Completed, "cluster run failed: {:?}", r.outcome);
}

#[test]
fn put_then_flush_is_visible_both_fabrics() {
    for ic in [Interconnect::Ib, Interconnect::Opa] {
        let spec = ClusterSpec::new(fabric(ic, 2), MpiConfig::optimized(4), 1);
        run_ok(spec, move |proc, _t| {
            let world = proc.comm_world();
            let win = proc.win_create(&world, 256);
            if proc.rank() == 0 {
                proc.put(&win, 1, 16, &[7u8; 32]);
                proc.win_flush(&win);
                proc.send(&world, 1, 1, &[]); // "put is flushed"
            } else {
                proc.recv(&world, vcmpi::mpi::Src::Rank(0), vcmpi::mpi::Tag::Value(1));
                assert_eq!(win.read_local(16, 32), vec![7u8; 32], "{ic:?}");
            }
            proc.win_free(&world, win);
        });
    }
}

#[test]
fn get_round_trip_both_fabrics() {
    for ic in [Interconnect::Ib, Interconnect::Opa] {
        let spec = ClusterSpec::new(fabric(ic, 2), MpiConfig::optimized(4), 1);
        run_ok(spec, move |proc, _t| {
            let world = proc.comm_world();
            let win = proc.win_create(&world, 128);
            if proc.rank() == 1 {
                win.write_local(0, &[0xEE; 64]);
            }
            proc.barrier(&world);
            if proc.rank() == 0 {
                let h = proc.get(&win, 1, 0, 64);
                proc.win_flush(&win);
                assert_eq!(proc.get_data(&win, h), vec![0xEE; 64], "{ic:?}");
            }
            proc.barrier(&world);
            proc.win_free(&world, win);
        });
    }
}

#[test]
fn accumulate_sums_from_many_ranks() {
    // 4 ranks each accumulate 1.5 into the same f64 cell on rank 0, twice.
    let spec = ClusterSpec::new(fabric(Interconnect::Opa, 4), MpiConfig::optimized(4), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        let win = proc.win_create(&world, 64);
        for _ in 0..2 {
            proc.accumulate(&win, 0, 8, &1.5f64.to_le_bytes(), AccOp::SumF64);
        }
        proc.win_flush(&win);
        proc.barrier(&world);
        if proc.rank() == 0 {
            let bytes = win.read_local(8, 8);
            let v = f64::from_le_bytes(bytes.try_into().unwrap());
            assert!((v - 12.0).abs() < 1e-12, "4 ranks x 2 x 1.5 = 12, got {v}");
        }
        proc.win_free(&world, win);
    });
}

#[test]
fn accumulate_program_order_preserved_by_default() {
    // Two ordered Replace accumulates from the same origin to the same
    // location: the later one must win (default accumulate_ordering).
    for ic in [Interconnect::Ib, Interconnect::Opa] {
        let spec = ClusterSpec::new(fabric(ic, 2), MpiConfig::optimized(4), 1);
        run_ok(spec, move |proc, _t| {
            let world = proc.comm_world();
            let win = proc.win_create(&world, 64);
            if proc.rank() == 0 {
                proc.accumulate(&win, 1, 0, &[1u8; 8], AccOp::Replace);
                proc.accumulate(&win, 1, 0, &[2u8; 8], AccOp::Replace);
                proc.win_flush(&win);
                proc.send(&world, 1, 1, &[]);
            } else {
                proc.recv(&world, vcmpi::mpi::Src::Rank(0), vcmpi::mpi::Tag::Value(1));
                assert_eq!(win.read_local(0, 8), vec![2u8; 8], "{ic:?}: program order");
            }
            proc.win_free(&world, win);
        });
    }
}

#[test]
fn fetch_and_op_is_an_atomic_counter() {
    // All 4 ranks hammer a shared u64 counter with fetch-and-add(1) x 8:
    // every rank must see a unique sequence of values, and the final count
    // must be 32.
    let spec = ClusterSpec::new(fabric(Interconnect::Opa, 4), MpiConfig::optimized(4), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        let win = proc.win_create(&world, 64);
        let mut fetched = Vec::new();
        for _ in 0..8 {
            let prev = proc.fetch_and_op(&win, 0, 0, &1u64.to_le_bytes(), AccOp::SumU64);
            fetched.push(u64::from_le_bytes(prev.try_into().unwrap()));
        }
        // Monotonically increasing per rank (no duplicated grants).
        for w in fetched.windows(2) {
            assert!(w[1] > w[0], "fetch_and_op must grant increasing values");
        }
        proc.barrier(&world);
        if proc.rank() == 0 {
            let v = u64::from_le_bytes(win.read_local(0, 8).try_into().unwrap());
            assert_eq!(v, 32);
        }
        proc.win_free(&world, win);
    });
}

#[test]
fn multiple_windows_are_independent_streams() {
    // Threads on distinct windows run concurrent RMA without interference.
    let spec = ClusterSpec::new(fabric(Interconnect::Ib, 2), MpiConfig::optimized(8), 4);
    use std::sync::Mutex;
    let wins: Arc<Mutex<std::collections::HashMap<usize, Vec<Arc<vcmpi::mpi::Window>>>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let bars: Arc<Vec<vcmpi::platform::PBarrier>> = Arc::new(
        (0..2).map(|_| vcmpi::platform::PBarrier::new(vcmpi::platform::Backend::Sim, 4)).collect(),
    );
    let w2 = wins.clone();
    run_ok(spec, move |proc, t| {
        if t == 0 {
            let world = proc.comm_world();
            let v: Vec<_> = (0..4).map(|_| proc.win_create(&world, 1024)).collect();
            w2.lock().unwrap().insert(proc.rank(), v);
        }
        bars[proc.rank()].wait();
        let win = w2.lock().unwrap().get(&proc.rank()).unwrap()[t].clone();
        let peer = 1 - proc.rank();
        let pattern = vec![t as u8 + 1; 128];
        proc.put(&win, peer, t * 128, &pattern);
        proc.win_flush(&win);
        bars[proc.rank()].wait();
        // Peer wrote into OUR window at the same offset with their pattern.
        assert_eq!(win.read_local(t * 128, 128), vec![t as u8 + 1; 128]);
        bars[proc.rank()].wait();
    });
}

#[test]
fn opa_put_needs_target_progress_ib_does_not() {
    // Measure flush latency on both fabrics while the target is busy
    // (no polling for 2ms). IB's hardware RMA should flush in ~wire time;
    // OPA's software RMA must wait for the target's service thread.
    let mut times = std::collections::HashMap::new();
    for ic in [Interconnect::Ib, Interconnect::Opa] {
        let spec = ClusterSpec::new(fabric(ic, 2), MpiConfig::optimized(4), 1);
        let r = run_cluster(spec, move |proc, _t| {
            let world = proc.comm_world();
            let win = proc.win_create(&world, 4096);
            if proc.rank() == 0 {
                let t0 = vcmpi::sim::now();
                proc.put(&win, 1, 0, &[1u8; 2048]);
                proc.win_flush(&win);
                vcmpi::mpi::world::record("flush_ns", (vcmpi::sim::now() - t0) as f64);
            } else {
                // Busy target: no MPI calls for 2ms.
                vcmpi::sim::advance(2_000_000);
            }
            proc.barrier(&world);
            proc.win_free(&world, win);
        });
        assert_eq!(r.outcome, SimOutcome::Completed);
        times.insert(ic, r.measurements["flush_ns"]);
    }
    let ib = times[&Interconnect::Ib];
    let opa = times[&Interconnect::Opa];
    assert!(
        ib < 100_000.0,
        "IB hardware put should flush in ~wire time, took {ib}ns"
    );
    assert!(
        opa > 5.0 * ib,
        "OPA software put should be much slower than IB with a busy target: opa={opa} ib={ib}"
    );
}
