//! Fig. 9 reproduction: the progress corner cases that make pure per-VCI
//! progress INCORRECT — and that the hybrid model fixes.
//!
//! These are valid MPI programs. With per-VCI-only progress
//! (`global_progress_interval = 0`) they livelock; with the hybrid model
//! they complete. Prior endpoint work ignored exactly this (paper §1, §8).

use std::sync::{Arc, Mutex};

use vcmpi::fabric::{FabricConfig, Interconnect};
use vcmpi::mpi::{run_cluster, ClusterSpec, LockKind, MpiConfig, Src, Tag};
use vcmpi::platform::{Backend, PBarrier};
use vcmpi::sim::SimOutcome;

fn fabric(ic: Interconnect) -> FabricConfig {
    FabricConfig { interconnect: ic, nodes: 2, procs_per_node: 1, max_contexts_per_node: 64 }
}

/// Fig. 9 (left), transcribed:
/// Rank 0:              MPI_Ssend(comm1); MPI_Ssend(comm2);
/// Rank 1 / Thread 0:   MPI_Irecv(comm1, req1); B; B; MPI_Wait(req1);
/// Rank 1 / Thread 1:   MPI_Irecv(comm2, req2); B; MPI_Wait(req2); B;
///
/// Ssend(comm1)'s ack requires rank 1 to *process* comm1's message; under
/// pure per-VCI progress, MPI_Wait(req2) polls only comm2's VCI, so the
/// ack never goes out, Ssend(comm2) is never issued, and nobody advances.
fn fig9_p2p(cfg: MpiConfig) -> SimOutcome {
    fig9_p2p_mixed(cfg, false)
}

/// `mixed = true` gives comm1 a striped+sharded policy via info keys while
/// comm2 stays ordered — the per-communicator mixed-policy configuration.
fn fig9_p2p_mixed(cfg: MpiConfig, mixed: bool) -> SimOutcome {
    let mut spec = ClusterSpec::new(fabric(Interconnect::Ib), cfg, 2);
    spec.time_limit = Some(10_000_000); // 10 virtual ms: plenty for valid runs
    spec.service_threads = false; // isolate: no PSM2-style savior
    let comms: Arc<Mutex<std::collections::HashMap<usize, (vcmpi::mpi::Comm, vcmpi::mpi::Comm)>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let setup: Arc<Vec<PBarrier>> =
        Arc::new((0..2).map(|_| PBarrier::new(Backend::Sim, 2)).collect());
    let omp = Arc::new(PBarrier::new(Backend::Sim, 2)); // rank 1's thread barrier
    let c2 = comms.clone();
    let r = run_cluster(spec, move |proc, t| {
        if t == 0 {
            let world = proc.comm_world();
            let c1 = if mixed {
                proc.comm_dup_with_info(
                    &world,
                    &vcmpi::mpi::Info::new()
                        .with("vcmpi_striping", "rr")
                        .with("vcmpi_match_shards", "4")
                        .with("vcmpi_rx_doorbell", "true"),
                )
            } else {
                proc.comm_dup(&world)
            };
            let c2_ = if mixed {
                proc.comm_dup_with_info(
                    &world,
                    &vcmpi::mpi::Info::new().with("vcmpi_striping", "off"),
                )
            } else {
                proc.comm_dup(&world)
            };
            c2.lock().unwrap().insert(proc.rank(), (c1, c2_));
        }
        setup[proc.rank()].wait();
        let (comm1, comm2) = c2.lock().unwrap().get(&proc.rank()).unwrap().clone();
        if proc.rank() == 0 {
            if t == 0 {
                proc.ssend(&comm1, 1, 1, &[1]);
                proc.ssend(&comm2, 1, 2, &[2]);
            }
            // t == 1 idles.
        } else if t == 0 {
            let req1 = proc.irecv(&comm1, Src::Rank(0), Tag::Value(1));
            omp.wait();
            omp.wait();
            proc.wait(req1);
        } else {
            let req2 = proc.irecv(&comm2, Src::Rank(0), Tag::Value(2));
            omp.wait();
            proc.wait(req2);
            omp.wait();
        }
    });
    r.outcome
}

#[test]
fn fig9_p2p_pure_per_vci_progress_hangs() {
    let mut cfg = MpiConfig::optimized(8);
    cfg.global_progress_interval = 0; // pure per-VCI: INCORRECT
    let out = fig9_p2p(cfg);
    assert!(
        matches!(out, SimOutcome::TimeLimit | SimOutcome::Deadlock),
        "expected livelock/deadlock, got {out:?}"
    );
}

#[test]
fn fig9_p2p_hybrid_progress_completes() {
    let cfg = MpiConfig::optimized(8); // hybrid (interval=64)
    assert_eq!(fig9_p2p(cfg), SimOutcome::Completed);
}

#[test]
fn fig9_p2p_single_vci_original_completes() {
    // With one VCI there is no distinction between per-VCI and global
    // progress — current MPI libraries complete this program.
    assert_eq!(fig9_p2p(MpiConfig::original()), SimOutcome::Completed);
}

#[test]
fn fig9_p2p_striped_completes() {
    // Per-message striping changes both the send fan-out and the progress
    // model (waiters sweep the pool), but Fig. 9's cross-VCI dependency
    // pattern must still complete.
    assert_eq!(fig9_p2p(MpiConfig::striped(8)), SimOutcome::Completed);
}

#[test]
fn fig9_p2p_striped_sharded_doorbell_completes() {
    // Sharded matching + doorbell-gated sweeps must not reintroduce the
    // Fig. 9 deadlock: a skipped sweep (no doorbell rung) still advances
    // virtual time, and the paranoid global round bounds a lost doorbell.
    assert_eq!(fig9_p2p(MpiConfig::striped_sharded(8)), SimOutcome::Completed);
}

#[test]
fn fig9_p2p_mixed_policy_completes() {
    // Per-communicator policies: comm1 striped+sharded via info keys on a
    // process whose default is NOT striped, comm2 explicitly ordered
    // (pinned out of the stripe lanes). The cross-VCI dependency pattern
    // must still complete under hybrid progress — the striped comm's
    // waiter sweeps only stripe lanes, so the ordered comm's completion
    // depends on the global-round backstop exactly like per-VCI progress.
    assert_eq!(fig9_p2p_mixed(MpiConfig::optimized(8), true), SimOutcome::Completed);
    // And with a striped process default + ordered override, too.
    assert_eq!(fig9_p2p_mixed(MpiConfig::striped_sharded(8), true), SimOutcome::Completed);
}

#[test]
fn dedicated_lane_allreduce_completes_under_striped_p2p_storm() {
    // The collectives-policy deadlock case: thread 0 on every proc runs
    // dedicated-lane allreduces while the remaining threads drive a
    // striped p2p storm over an info-keyed hot comm on the same pool.
    // The reserved lane is pinned out of the striped sweep, so the
    // collective's completion depends on its own lane polling plus the
    // global-round backstop — it must complete, never starve.
    let r = vcmpi::bench::coll_rate_run(vcmpi::bench::CollRateParams {
        mode: vcmpi::bench::CollMode::CollDedicatedStorm,
        threads: 4,
        elems: 4096,
        reps: 2,
        segments: 4,
        storm_msgs: 128,
        cfg_override: None,
    });
    assert!(r.rate > 0.0, "dedicated-lane allreduce must make progress under the storm");
}

#[test]
fn outstanding_iallreduces_on_distinct_comms_complete_under_striped_storm() {
    // Nonblocking-collectives deadlock case: thread 0 on every proc
    // issues THREE iallreduces on distinct dedicated comms and leaves
    // them all outstanding while the remaining threads drive a striped
    // p2p storm over an info-keyed hot comm on the same pool. The
    // schedules advance only via progress hooks fired from whoever polls
    // (the storm threads' waits included) plus the waiter's own loop —
    // every collective must complete and reduce correctly, never starve
    // behind the storm or each other.
    const NCOLL: usize = 3;
    const ELEMS: usize = 2048;
    let mut spec = ClusterSpec::new(fabric(Interconnect::Ib), MpiConfig::optimized(8), 3);
    spec.time_limit = Some(1_000_000_000); // 1 virtual s: plenty for valid runs
    spec.service_threads = false;
    type CommSet = (Vec<vcmpi::mpi::Comm>, vcmpi::mpi::Comm);
    let comms: Arc<Mutex<std::collections::HashMap<usize, CommSet>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let setup: Arc<Vec<PBarrier>> =
        Arc::new((0..2).map(|_| PBarrier::new(Backend::Sim, 3)).collect());
    let c2 = comms.clone();
    let r = run_cluster(spec, move |proc, t| {
        if t == 0 {
            let world = proc.comm_world();
            let coll: Vec<_> = (0..NCOLL)
                .map(|_| {
                    proc.comm_dup_with_info(
                        &world,
                        &vcmpi::mpi::Info::new()
                            .with("vcmpi_collectives", "dedicated")
                            .with("vcmpi_coll_segments", "4"),
                    )
                })
                .collect();
            let hot = proc.comm_dup_with_info(
                &world,
                &vcmpi::mpi::Info::new()
                    .with("vcmpi_striping", "rr")
                    .with("vcmpi_match_shards", "4"),
            );
            c2.lock().unwrap().insert(proc.rank(), (coll, hot));
        }
        setup[proc.rank()].wait();
        let (coll, hot) = c2.lock().unwrap().get(&proc.rank()).unwrap().clone();
        let peer = 1 - proc.rank();
        if t == 0 {
            // Issue all N, keep them outstanding, then wait newest-first
            // so every wait still has older schedules in flight.
            let data: Vec<Vec<f32>> = (0..NCOLL)
                .map(|c| {
                    (0..ELEMS)
                        .map(|i| ((proc.rank() * 100 + c * 10 + i) % 13) as f32)
                        .collect()
                })
                .collect();
            let mut reqs: Vec<_> = coll
                .iter()
                .zip(data.iter())
                .map(|(comm, d)| proc.iallreduce_f32(comm, d))
                .collect();
            let mut c = NCOLL;
            while let Some(req) = reqs.pop() {
                c -= 1;
                let mut out = vec![0.0f32; ELEMS];
                proc.coll_wait_f32(req, &mut out);
                for (i, &v) in out.iter().enumerate() {
                    let want: f32 =
                        (0..2).map(|rk| ((rk * 100 + c * 10 + i) % 13) as f32).sum();
                    assert!(
                        (v - want).abs() < 1e-4,
                        "comm {c} elem {i}: got {v}, want {want}"
                    );
                }
            }
            for comm in coll {
                proc.comm_free(comm);
            }
        } else {
            // Striped p2p storm, tag-disjoint per thread.
            let payload = vec![t as u8; 512];
            for _ in 0..64 {
                proc.send(&hot, peer, t as i32, &payload);
                let rr = proc.irecv(&hot, Src::Rank(peer), Tag::Value(t as i32));
                proc.wait(rr);
            }
        }
    });
    assert_eq!(r.outcome, SimOutcome::Completed);
}

#[test]
fn streamed_comm_completes_under_striped_p2p_storm() {
    // Serial-execution-stream deadlock case: thread 0 on every proc drives
    // a `vcmpi_stream=local` comm (auto-bound to a dedicated single-writer
    // lane on first use) through a ping-pong while the remaining threads
    // hammer a striped+sharded hot comm over the same pool. The stream
    // lane is pinned out of the striped sweep AND skipped by every other
    // thread's global round (no foreign thread may enter a single-writer
    // VCI), so the stream's completion depends entirely on its owner's
    // lock-free polling — it must complete, never starve, and the storm's
    // sweeps must never trip the cross-thread tripwire.
    const ROUNDS: usize = 32;
    let mut spec = ClusterSpec::new(fabric(Interconnect::Ib), MpiConfig::optimized(8), 3);
    spec.time_limit = Some(1_000_000_000); // 1 virtual s: plenty for valid runs
    spec.service_threads = false;
    type CommPair = (vcmpi::mpi::Comm, vcmpi::mpi::Comm);
    let comms: Arc<Mutex<std::collections::HashMap<usize, CommPair>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let setup: Arc<Vec<PBarrier>> =
        Arc::new((0..2).map(|_| PBarrier::new(Backend::Sim, 3)).collect());
    let c2 = comms.clone();
    let r = run_cluster(spec, move |proc, t| {
        if t == 0 {
            let world = proc.comm_world();
            let streamed = proc.comm_dup_with_info(
                &world,
                &vcmpi::mpi::Info::new().with("vcmpi_stream", "local"),
            );
            let hot = proc.comm_dup_with_info(
                &world,
                &vcmpi::mpi::Info::new()
                    .with("vcmpi_striping", "rr")
                    .with("vcmpi_match_shards", "4")
                    .with("vcmpi_rx_doorbell", "true"),
            );
            c2.lock().unwrap().insert(proc.rank(), (streamed, hot));
        }
        setup[proc.rank()].wait();
        let (streamed, hot) = c2.lock().unwrap().get(&proc.rank()).unwrap().clone();
        let peer = 1 - proc.rank();
        if t == 0 {
            for i in 0..ROUNDS {
                let ball = vec![i as u8; 256];
                if proc.rank() == 0 {
                    proc.send(&streamed, peer, 7, &ball);
                    assert_eq!(proc.recv(&streamed, Src::Rank(peer), Tag::Value(7)), ball);
                } else {
                    assert_eq!(proc.recv(&streamed, Src::Rank(peer), Tag::Value(7)), ball);
                    proc.send(&streamed, peer, 7, &ball);
                }
            }
            // Unbind (and return the lane to the stripe set) before
            // finalize's no-stream-owned-lanes tripwire runs.
            proc.comm_free(streamed);
        } else {
            // Striped p2p storm, tag-disjoint per thread.
            let payload = vec![t as u8; 512];
            for _ in 0..64 {
                proc.send(&hot, peer, t as i32, &payload);
                let rr = proc.irecv(&hot, Src::Rank(peer), Tag::Value(t as i32));
                proc.wait(rr);
            }
        }
    });
    assert_eq!(r.outcome, SimOutcome::Completed);
}

/// Fig. 9 (right), transcribed (software-RMA fabric, large Gets):
/// Rank 0:              Get(win1); Get(win2); flush(win1); flush(win2);
/// Rank 1 / Thread 0:   Get(win1); B; B; flush(win1);
/// Rank 1 / Thread 1:   Get(win2); B; flush(win2); B;
///
/// Every flush needs the *remote* side to serve the Get's active message;
/// under pure per-VCI progress each spinner serves only its own window's
/// VCI and the four flushes starve each other.
fn fig9_rma(cfg: MpiConfig) -> SimOutcome {
    let mut spec = ClusterSpec::new(fabric(Interconnect::Opa), cfg, 2);
    spec.time_limit = Some(10_000_000);
    spec.service_threads = false;
    let wins: Arc<Mutex<std::collections::HashMap<usize, (Arc<vcmpi::mpi::Window>, Arc<vcmpi::mpi::Window>)>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let setup: Arc<Vec<PBarrier>> =
        Arc::new((0..2).map(|_| PBarrier::new(Backend::Sim, 2)).collect());
    let omp = Arc::new(PBarrier::new(Backend::Sim, 2));
    let w2 = wins.clone();
    const LEN: usize = 32 * 1024;
    let r = run_cluster(spec, move |proc, t| {
        let world = proc.comm_world();
        if t == 0 {
            let a = proc.win_create(&world, LEN);
            let b = proc.win_create(&world, LEN);
            w2.lock().unwrap().insert(proc.rank(), (a, b));
        }
        setup[proc.rank()].wait();
        let (win1, win2) = w2.lock().unwrap().get(&proc.rank()).unwrap().clone();
        let peer = 1 - proc.rank();
        if proc.rank() == 0 {
            if t == 0 {
                let h1 = proc.get(&win1, peer, 0, LEN);
                let h2 = proc.get(&win2, peer, 0, LEN);
                proc.win_flush(&win1);
                proc.win_flush(&win2);
                let _ = (proc.get_data(&win1, h1), proc.get_data(&win2, h2));
            }
        } else if t == 0 {
            let h = proc.get(&win1, peer, 0, LEN);
            omp.wait();
            omp.wait();
            proc.win_flush(&win1);
            let _ = proc.get_data(&win1, h);
        } else {
            let h = proc.get(&win2, peer, 0, LEN);
            omp.wait();
            proc.win_flush(&win2);
            let _ = proc.get_data(&win2, h);
            omp.wait();
        }
    });
    r.outcome
}

#[test]
fn fig9_rma_pure_per_vci_progress_hangs() {
    let mut cfg = MpiConfig::optimized(8);
    cfg.global_progress_interval = 0;
    let out = fig9_rma(cfg);
    assert!(
        matches!(out, SimOutcome::TimeLimit | SimOutcome::Deadlock),
        "expected livelock/deadlock, got {out:?}"
    );
}

#[test]
fn fig9_rma_hybrid_progress_completes() {
    let cfg = MpiConfig::optimized(8);
    assert_eq!(fig9_rma(cfg), SimOutcome::Completed);
}

#[test]
fn psm2_service_thread_rescues_pure_per_vci() {
    // With the OPA service thread enabled (the deployment default), even
    // pure per-VCI progress eventually completes — slowly. This is the
    // paper's "relies on its low-frequency progress thread" observation.
    let mut cfg = MpiConfig::optimized(8);
    cfg.global_progress_interval = 0;
    let mut spec = ClusterSpec::new(fabric(Interconnect::Opa), cfg, 1);
    spec.time_limit = Some(60_000_000_000);
    spec.service_threads = true;
    let r = run_cluster(spec, move |proc, _t| {
        let world = proc.comm_world();
        let win = proc.win_create(&world, 4096);
        if proc.rank() == 0 {
            proc.put(&win, 1, 0, &[5u8; 1024]);
            proc.win_flush(&win); // completes only via target's svc thread
            proc.send(&world, 1, 3, &[]);
        } else {
            let done = proc.irecv(&world, Src::Rank(0), Tag::Value(3));
            proc.wait(done);
            assert_eq!(win.read_local(0, 1024), vec![5u8; 1024]);
        }
        proc.barrier(&world);
        proc.win_free(&world, win);
    });
    assert_eq!(r.outcome, SimOutcome::Completed);
}

#[test]
fn exclusive_lock_contention_serializes_increments_under_striped_storm() {
    // Passive-target mutual-exclusion liveness: ranks 0-2 contend for an
    // EXCLUSIVE lock on rank 3's window and each performs 5 lock-protected
    // read-modify-write increments of the same cell, while a second thread
    // on every proc drives a striped p2p storm over the same VCI pool.
    // The target-side FIFO lock table must grant every queued request
    // exactly once (no starvation behind the storm, no double grant), and
    // unlock's per-target flush must complete the put before the next
    // holder's get — the final cell value proves mutual exclusion AND
    // liveness: 3 ranks x 5 increments == 15 with no lost update.
    const ROUNDS: usize = 5;
    let fab = FabricConfig {
        interconnect: Interconnect::Opa,
        nodes: 4,
        procs_per_node: 1,
        max_contexts_per_node: 64,
    };
    let mut spec = ClusterSpec::new(fab, MpiConfig::optimized(8), 2);
    spec.time_limit = Some(1_000_000_000); // 1 virtual s: plenty for valid runs
    type Shared = (Arc<vcmpi::mpi::Window>, vcmpi::mpi::Comm);
    let shared: Arc<Mutex<std::collections::HashMap<usize, Shared>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let setup: Arc<Vec<PBarrier>> =
        Arc::new((0..4).map(|_| PBarrier::new(Backend::Sim, 2)).collect());
    let s2 = shared.clone();
    let r = run_cluster(spec, move |proc, t| {
        if t == 0 {
            let world = proc.comm_world();
            let win = proc.win_create(&world, 64);
            let hot = proc.comm_dup_with_info(
                &world,
                &vcmpi::mpi::Info::new()
                    .with("vcmpi_striping", "rr")
                    .with("vcmpi_match_shards", "4"),
            );
            s2.lock().unwrap().insert(proc.rank(), (win, hot));
        }
        setup[proc.rank()].wait();
        let (win, hot) = s2.lock().unwrap().get(&proc.rank()).unwrap().clone();
        if t == 0 {
            let world = proc.comm_world();
            if proc.rank() < 3 {
                for _ in 0..ROUNDS {
                    proc.win_lock(&win, LockKind::Exclusive, 3);
                    let h = proc.get(&win, 3, 0, 8);
                    proc.win_flush(&win);
                    let cur =
                        u64::from_le_bytes(proc.get_data(&win, h).try_into().unwrap());
                    proc.put(&win, 3, 0, &(cur + 1).to_le_bytes());
                    proc.win_unlock(&win, 3); // completes the put remotely
                }
                proc.send(&world, 3, 9, &[]);
            } else {
                for rk in 0..3 {
                    let done = proc.irecv(&world, Src::Rank(rk), Tag::Value(9));
                    proc.wait(done);
                }
                let want = (3 * ROUNDS) as u64;
                assert_eq!(
                    win.read_local(0, 8),
                    want.to_le_bytes().to_vec(),
                    "lost update: exclusive epochs failed to serialize increments"
                );
            }
            proc.barrier(&world);
            proc.win_free(&world, win);
        } else {
            // Striped p2p storm, tag-disjoint per thread.
            let peer = proc.rank() ^ 1;
            let payload = vec![t as u8; 512];
            for _ in 0..64 {
                proc.send(&hot, peer, t as i32, &payload);
                let rr = proc.irecv(&hot, Src::Rank(peer), Tag::Value(t as i32));
                proc.wait(rr);
            }
        }
    });
    assert_eq!(r.outcome, SimOutcome::Completed);
}

#[test]
fn context_hard_fail_mid_storm_completes_via_failover() {
    // Robustness companion to the Fig. 9 cases: instead of a progress
    // policy starving a lane, the *hardware* takes one away. Proc 1's
    // context 3 hard-fails at t = 150 us — mid-storm, with eager frames,
    // acks and reorder state in flight on that lane — under a background
    // drop plan that keeps the retransmit path busy at the same time.
    // The run must complete (quarantine the lane, migrate its state to a
    // survivor, redirect in-flight frames, replay the unacked window) and
    // the Table-1 failover counter must show the recovery actually ran;
    // completion-by-luck with a silently idle dead lane would not count.
    let kill_at_ns: u64 = 150_000;
    let mut cfg = MpiConfig::striped(6);
    cfg.fault_plan = Some(format!("seed=99,drop=30,kill=1:3@{kill_at_ns}"));
    let mut spec = ClusterSpec::new(fabric(Interconnect::Opa), cfg, 3);
    spec.time_limit = Some(60_000_000_000); // 60 virtual s: storm + recovery
    let failovers_before = vcmpi::mpi::instrument::proc_counters().failovers;
    let r = run_cluster(spec, |proc, t| {
        let world = proc.comm_world();
        let peer = proc.rank() ^ 1;
        // Tag-disjoint striped streams per thread, long enough to
        // straddle the kill time comfortably on every lane.
        let payload = vec![t as u8; 768];
        for k in 0..96u64 {
            let sr = proc.isend(&world, peer, t as i32, &payload);
            let got = proc.recv(&world, Src::Rank(peer), Tag::Value(t as i32));
            assert_eq!(got.len(), 768, "storm payload truncated (iteration {k})");
            assert!(got.iter().all(|&b| b == t as u8), "storm payload mangled");
            proc.wait(sr);
        }
        proc.barrier(&world);
    });
    assert_eq!(
        r.outcome,
        SimOutcome::Completed,
        "a context hard-fail mid-storm must fail over, not deadlock"
    );
    assert!(
        r.time_ns > kill_at_ns,
        "run ended before the scheduled kill ({} <= {kill_at_ns}): not mid-storm",
        r.time_ns
    );
    let failovers_after = vcmpi::mpi::instrument::proc_counters().failovers;
    assert!(
        failovers_after > failovers_before,
        "completed without recording a lane failover — the dead lane was never recovered"
    );
    let drops = r.measurements.get("fault_drops").copied().unwrap_or(0.0);
    assert!(drops > 0.0, "background drop plan never fired");
}
