//! Collectives over p2p: barrier, bcast, allgather, allreduce.

use vcmpi::fabric::{FabricConfig, Interconnect};
use vcmpi::mpi::{run_cluster, ClusterSpec, MpiConfig, MpiProc};
use vcmpi::sim::SimOutcome;

fn spec(nodes: usize) -> ClusterSpec {
    ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Ib,
            nodes,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        MpiConfig::optimized(4),
        1,
    )
}

fn run_ok(
    s: ClusterSpec,
    body: impl Fn(&std::sync::Arc<MpiProc>, usize) + Send + Sync + 'static,
) {
    let r = run_cluster(s, body);
    assert_eq!(r.outcome, SimOutcome::Completed, "{:?}", r.outcome);
}

#[test]
fn barrier_orders_virtual_time() {
    // The slowest rank (3ms of compute) gates everyone's exit.
    run_ok(spec(4), |proc, _t| {
        let world = proc.comm_world();
        if proc.rank() == 2 {
            vcmpi::sim::advance(3_000_000);
        }
        proc.barrier(&world);
        assert!(vcmpi::sim::now() >= 3_000_000, "rank {} escaped early", proc.rank());
    });
}

#[test]
fn bcast_from_each_root() {
    for root in 0..4 {
        run_ok(spec(4), move |proc, _t| {
            let world = proc.comm_world();
            let data = if proc.rank() == root {
                Some(vec![root as u8; 100])
            } else {
                None
            };
            let got = proc.bcast(&world, root, data);
            assert_eq!(got, vec![root as u8; 100]);
        });
    }
}

#[test]
fn allgather_collects_in_rank_order() {
    run_ok(spec(5), |proc, _t| {
        let world = proc.comm_world();
        let mine = vec![proc.rank() as u8; 3 + proc.rank()];
        let all = proc.allgather_bytes(&world, &mine);
        assert_eq!(all.len(), 5);
        for (r, blob) in all.iter().enumerate() {
            assert_eq!(blob, &vec![r as u8; 3 + r]);
        }
    });
}

#[test]
fn ring_allreduce_sums_f32() {
    for n in [2, 3, 4, 8] {
        run_ok(spec(n), move |proc, _t| {
            let world = proc.comm_world();
            // Buffer length deliberately not divisible by n.
            let len = 1000 + 7;
            let mut data: Vec<f32> = (0..len).map(|i| (proc.rank() + 1) as f32 * i as f32).collect();
            proc.allreduce_f32(&world, &mut data);
            let scale: f32 = (1..=n).map(|r| r as f32).sum();
            for (i, &v) in data.iter().enumerate() {
                let want = scale * i as f32;
                assert!(
                    (v - want).abs() <= want.abs() * 1e-5 + 1e-3,
                    "n={n} idx={i}: got {v}, want {want}"
                );
            }
        });
    }
}

#[test]
fn allreduce_scalar_sums() {
    run_ok(spec(6), |proc, _t| {
        let world = proc.comm_world();
        let s = proc.allreduce_scalar(&world, (proc.rank() + 1) as f64);
        assert!((s - 21.0).abs() < 1e-12);
    });
}

#[test]
fn collectives_do_not_cross_match_user_traffic() {
    // User messages with tags colliding numerically with nothing internal:
    // run a barrier between user isend and recv to stress the matcher.
    run_ok(spec(2), |proc, _t| {
        let world = proc.comm_world();
        let peer = 1 - proc.rank();
        let sreq = proc.isend(&world, peer, 5, &[9u8; 8]);
        proc.barrier(&world);
        let got = proc.recv(&world, vcmpi::mpi::Src::Rank(peer), vcmpi::mpi::Tag::Value(5));
        assert_eq!(got, vec![9u8; 8]);
        proc.wait(sreq);
    });
}
