//! Collectives over p2p: barrier, bcast, allgather, allreduce — including
//! the segmented/pipelined engine under every `vcmpi_collectives` policy
//! and the dedicated-lane reserve/release lifecycle.

use vcmpi::fabric::{FabricConfig, Interconnect};
use vcmpi::mpi::{run_cluster, ClusterSpec, Info, MpiConfig, MpiProc};
use vcmpi::sim::SimOutcome;

fn spec(nodes: usize) -> ClusterSpec {
    ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Ib,
            nodes,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        MpiConfig::optimized(4),
        1,
    )
}

fn run_ok(
    s: ClusterSpec,
    body: impl Fn(&std::sync::Arc<MpiProc>, usize) + Send + Sync + 'static,
) {
    let r = run_cluster(s, body);
    assert_eq!(r.outcome, SimOutcome::Completed, "{:?}", r.outcome);
}

#[test]
fn barrier_orders_virtual_time() {
    // The slowest rank (3ms of compute) gates everyone's exit.
    run_ok(spec(4), |proc, _t| {
        let world = proc.comm_world();
        if proc.rank() == 2 {
            vcmpi::sim::advance(3_000_000);
        }
        proc.barrier(&world);
        assert!(vcmpi::sim::now() >= 3_000_000, "rank {} escaped early", proc.rank());
    });
}

#[test]
fn bcast_from_each_root() {
    for root in 0..4 {
        run_ok(spec(4), move |proc, _t| {
            let world = proc.comm_world();
            let data = if proc.rank() == root {
                Some(vec![root as u8; 100])
            } else {
                None
            };
            let got = proc.bcast(&world, root, data);
            assert_eq!(got, vec![root as u8; 100]);
        });
    }
}

#[test]
fn allgather_collects_in_rank_order() {
    run_ok(spec(5), |proc, _t| {
        let world = proc.comm_world();
        let mine = vec![proc.rank() as u8; 3 + proc.rank()];
        let all = proc.allgather_bytes(&world, &mine);
        assert_eq!(all.len(), 5);
        for (r, blob) in all.iter().enumerate() {
            assert_eq!(blob, &vec![r as u8; 3 + r]);
        }
    });
}

#[test]
fn ring_allreduce_sums_f32() {
    for n in [2, 3, 4, 8] {
        run_ok(spec(n), move |proc, _t| {
            let world = proc.comm_world();
            // Buffer length deliberately not divisible by n.
            let len = 1000 + 7;
            let mut data: Vec<f32> = (0..len).map(|i| (proc.rank() + 1) as f32 * i as f32).collect();
            proc.allreduce_f32(&world, &mut data);
            let scale: f32 = (1..=n).map(|r| r as f32).sum();
            for (i, &v) in data.iter().enumerate() {
                let want = scale * i as f32;
                assert!(
                    (v - want).abs() <= want.abs() * 1e-5 + 1e-3,
                    "n={n} idx={i}: got {v}, want {want}"
                );
            }
        });
    }
}

#[test]
fn allreduce_scalar_sums() {
    run_ok(spec(6), |proc, _t| {
        let world = proc.comm_world();
        let s = proc.allreduce_scalar(&world, (proc.rank() + 1) as f64);
        assert!((s - 21.0).abs() < 1e-12);
    });
}

#[test]
fn bcast_non_power_of_two_sizes_and_nonzero_roots() {
    // Regression for the binomial child computation (the seed carried a
    // dead guard block): every (size, root) pair must deliver, including
    // non-power-of-two sizes where the deepest subtree is truncated, and
    // payloads whose length does not divide the segment count.
    for n in [3usize, 5, 6, 7] {
        for root in 0..n {
            run_ok(spec(n), move |proc, _t| {
                let world = proc.comm_world();
                let payload: Vec<u8> = (0..37).map(|i| (root * 31 + i) as u8).collect();
                let data = if proc.rank() == root { Some(payload.clone()) } else { None };
                let got = proc.bcast(&world, root, data);
                assert_eq!(got, payload, "n={n} root={root} rank={}", proc.rank());
            });
        }
    }
}

#[test]
fn segmented_allreduce_matches_oracle_under_all_collectives_policies() {
    // The same reduction, under each `vcmpi_collectives` lane mapping
    // (inherit on an ordered comm, inherit on a striped comm, dedicated,
    // striped) and a non-default segment count: all must agree with the
    // host-computed oracle. Buffer length deliberately not divisible by
    // the comm size or the segment count.
    let arms: Vec<(&str, Option<(&str, &str)>, MpiConfig)> = vec![
        ("inherit/ordered", None, MpiConfig::optimized(6)),
        ("inherit/striped", None, MpiConfig::striped_sharded(6)),
        ("dedicated", Some(("vcmpi_collectives", "dedicated")), MpiConfig::optimized(6)),
        ("striped", Some(("vcmpi_collectives", "striped")), MpiConfig::optimized(6)),
    ];
    for (label, key, cfg) in arms {
        let label = label.to_string();
        let spec = ClusterSpec::new(
            FabricConfig {
                interconnect: Interconnect::Ib,
                nodes: 4,
                procs_per_node: 1,
                max_contexts_per_node: 64,
            },
            cfg,
            1,
        );
        let r = run_cluster(spec, move |proc, _t| {
            let world = proc.comm_world();
            let mut info = Info::new().with("vcmpi_coll_segments", "3");
            if let Some((k, v)) = key {
                info.set(k, v);
            }
            let comm = proc.comm_dup_with_info(&world, &info);
            let len = 1000 + 7;
            let mut data: Vec<f32> =
                (0..len).map(|i| (proc.rank() + 1) as f32 * i as f32).collect();
            proc.allreduce_f32(&comm, &mut data);
            let scale: f32 = (1..=4).map(|r| r as f32).sum();
            for (i, &v) in data.iter().enumerate() {
                let want = scale * i as f32;
                assert!(
                    (v - want).abs() <= want.abs() * 1e-5 + 1e-3,
                    "{label} idx={i}: got {v}, want {want}"
                );
            }
            // Scalar metrics ride the same segmented ring.
            let s = proc.allreduce_scalar(&comm, (proc.rank() + 1) as f64);
            assert!((s - 10.0).abs() < 1e-12, "{label}: scalar sum {s}");
            proc.comm_free(comm);
        });
        assert_eq!(r.outcome, SimOutcome::Completed, "{:?}", r.outcome);
    }
}

#[test]
fn dedicated_collective_lane_is_pinned_then_released_at_comm_free() {
    // The dedicated-lane lifecycle: first collective reserves (pins) the
    // comm's lane out of the stripe set; comm_free releases it (the
    // finalize tripwire stays clean — the run completing proves it).
    let spec2 = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Ib,
            nodes: 2,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        MpiConfig::optimized(6),
        1,
    );
    run_ok(spec2, |proc, _t| {
        let world = proc.comm_world();
        let comm = proc
            .comm_dup_with_info(&world, &Info::new().with("vcmpi_collectives", "dedicated"));
        let lane = proc.dedicated_coll_lane(&comm);
        assert_ne!(lane, 0, "the fallback lane is never a dedicated lane");
        assert!(proc.stripe_lane_pinned(lane), "reserving pins the lane");
        // Collectives route over the reserved lane and still work.
        proc.barrier(&comm);
        let mut v = vec![1.0f32; 97];
        proc.allreduce_f32(&comm, &mut v);
        assert!(v.iter().all(|&x| (x - 2.0).abs() < 1e-6));
        assert!(proc.stripe_lane_pinned(lane), "pin survives the collectives");
        proc.comm_free(comm);
        assert!(!proc.stripe_lane_pinned(lane), "comm_free releases the reserved lane");
    });
}

#[test]
fn two_dedicated_comms_get_distinct_lanes_on_a_small_pool() {
    // Regression: dedicated-lane placement used to be a pure comm-id
    // hash, so two dedicated comms could collide on one lane and
    // serialize each other's collectives. Placement is now least-loaded
    // (tiebroken by a scrambled probe start, symmetric because
    // placements happen in comm-creation order): on a small pool with
    // exactly two candidate lanes, two dedicated comms MUST occupy both.
    let spec2 = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Ib,
            nodes: 2,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        MpiConfig::optimized(3),
        1,
    );
    run_ok(spec2, |proc, _t| {
        let world = proc.comm_world();
        let ded = Info::new().with("vcmpi_collectives", "dedicated");
        let a = proc.comm_dup_with_info(&world, &ded);
        let b = proc.comm_dup_with_info(&world, &ded);
        let la = proc.dedicated_coll_lane(&a);
        let lb = proc.dedicated_coll_lane(&b);
        assert_ne!(la, 0, "the fallback lane is never a dedicated lane");
        assert_ne!(lb, 0, "the fallback lane is never a dedicated lane");
        assert_ne!(la, lb, "two dedicated comms must not share a lane while the pool has two");
        assert!(proc.stripe_lane_pinned(la) && proc.stripe_lane_pinned(lb));
        // Both comms' collectives work over their reserved lanes.
        let mut va = vec![1.0f32; 61];
        proc.allreduce_f32(&a, &mut va);
        assert!(va.iter().all(|&x| (x - 2.0).abs() < 1e-6));
        let mut vb = vec![2.0f32; 61];
        proc.allreduce_f32(&b, &mut vb);
        assert!(vb.iter().all(|&x| (x - 4.0).abs() < 1e-6));
        proc.comm_free(a);
        proc.comm_free(b);
        assert!(!proc.stripe_lane_pinned(la) && !proc.stripe_lane_pinned(lb));
    });
}

#[test]
fn iallreduce_overlaps_across_comms_and_coll_test_polls() {
    // Two nonblocking allreduces in flight at once on distinct comms
    // (the tag-space contract allows one per comm), completed out of
    // issue order: poll the first with coll_test, wait the second first.
    run_ok(spec(3), |proc, _t| {
        let world = proc.comm_world();
        let a = proc.comm_dup(&world);
        let b = proc.comm_dup(&world);
        let len = 257;
        let xs: Vec<f32> = (0..len).map(|i| (proc.rank() + 1) as f32 + i as f32).collect();
        let ys: Vec<f32> = (0..len).map(|i| 2.0 * i as f32).collect();
        let ra = proc.iallreduce_f32(&a, &xs);
        let rb = proc.iallreduce_f32(&b, &ys);
        let mut outb = vec![0.0f32; len];
        proc.coll_wait_f32(rb, &mut outb);
        while !proc.coll_test(&ra) {}
        let mut outa = vec![0.0f32; len];
        proc.coll_wait_f32(ra, &mut outa);
        for i in 0..len {
            let want_a = 6.0 + 3.0 * i as f32; // sum of (r+1) + i over 3 ranks
            let want_b = 6.0 * i as f32;
            assert!((outa[i] - want_a).abs() <= want_a.abs() * 1e-5 + 1e-3);
            assert!((outb[i] - want_b).abs() <= want_b.abs() * 1e-5 + 1e-3);
        }
        proc.comm_free(a);
        proc.comm_free(b);
    });
}

#[test]
fn ibcast_delivers_while_root_computes() {
    // The root issues the ibcast and "computes" before waiting; interior
    // nodes forward segments as they land (driven by the waiters'
    // progress + hook 0).
    for root in [0usize, 2] {
        run_ok(spec(5), move |proc, _t| {
            let world = proc.comm_world();
            let payload: Vec<u8> = (0..149).map(|i| (root * 17 + i) as u8).collect();
            let data = if proc.rank() == root { Some(payload.clone()) } else { None };
            let req = proc.ibcast(&world, root, data);
            vcmpi::sim::advance(50_000);
            let got = proc.coll_wait(req);
            assert_eq!(got, payload, "root={root} rank={}", proc.rank());
        });
    }
}

#[test]
fn collectives_do_not_cross_match_user_traffic() {
    // User messages with tags colliding numerically with nothing internal:
    // run a barrier between user isend and recv to stress the matcher.
    run_ok(spec(2), |proc, _t| {
        let world = proc.comm_world();
        let peer = 1 - proc.rank();
        let sreq = proc.isend(&world, peer, 5, &[9u8; 8]);
        proc.barrier(&world);
        let got = proc.recv(&world, vcmpi::mpi::Src::Rank(peer), vcmpi::mpi::Tag::Value(5));
        assert_eq!(got, vec![9u8; 8]);
        proc.wait(sreq);
    });
}
