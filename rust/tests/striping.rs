//! Per-message VCI striping with receiver-side seq reordering: end-to-end
//! semantics across the full stack, plus the wire-robustness regressions
//! (stale/duplicate/malformed control messages must never abort).

use std::sync::{Arc, Mutex};

use vcmpi::fabric::{FabricConfig, Interconnect, P2pProtocol, Payload};
use vcmpi::mpi::{run_cluster, ClusterSpec, Info, MpiConfig, Src, Tag, VciStriping};
use vcmpi::platform::{Backend, PBarrier};
use vcmpi::sim::SimOutcome;

fn fabric(ic: Interconnect, nodes: usize) -> FabricConfig {
    FabricConfig { interconnect: ic, nodes, procs_per_node: 1, max_contexts_per_node: 64 }
}

fn run_ok(
    spec: ClusterSpec,
    body: impl Fn(&Arc<vcmpi::mpi::MpiProc>, usize) + Send + Sync + 'static,
) {
    let r = run_cluster(spec, body);
    assert_eq!(r.outcome, SimOutcome::Completed, "cluster run failed: {:?}", r.outcome);
}

fn striped_configs() -> Vec<(&'static str, MpiConfig)> {
    let mut hashed = MpiConfig::striped(8);
    hashed.vci_striping = VciStriping::HashedByRequest;
    let mut hashed_sharded = MpiConfig::striped_sharded(8);
    hashed_sharded.vci_striping = VciStriping::HashedByRequest;
    vec![
        ("round_robin", MpiConfig::striped(8)),
        ("hashed", hashed),
        ("round_robin+sharded", MpiConfig::striped_sharded(8)),
        ("hashed+sharded", hashed_sharded),
    ]
}

#[test]
fn striped_ping_pong_both_fabrics() {
    for ic in [Interconnect::Opa, Interconnect::Ib] {
        for (name, cfg) in striped_configs() {
            let spec = ClusterSpec::new(fabric(ic, 2), cfg, 1);
            run_ok(spec, move |proc, _t| {
                let world = proc.comm_world();
                if proc.rank() == 0 {
                    proc.send(&world, 1, 7, &[0xAB; 64]);
                    let back = proc.recv(&world, Src::Rank(1), Tag::Value(8));
                    assert_eq!(back, vec![0xCD; 32], "echo payload ({name})");
                } else {
                    let got = proc.recv(&world, Src::Rank(0), Tag::Value(7));
                    assert_eq!(got, vec![0xAB; 64], "ping payload ({name})");
                    proc.send(&world, 0, 8, &[0xCD; 32]);
                }
            });
        }
    }
}

#[test]
fn striped_nonovertaking_same_envelope() {
    // 80 back-to-back sends with the same envelope fan out across 8 VCIs;
    // the receiver-side reorder stage must still deliver them in program
    // order (MPI's nonovertaking rule).
    for (name, cfg) in striped_configs() {
        let spec = ClusterSpec::new(fabric(Interconnect::Opa, 2), cfg, 1);
        run_ok(spec, move |proc, _t| {
            let world = proc.comm_world();
            if proc.rank() == 0 {
                for i in 0..80u32 {
                    proc.send(&world, 1, 3, &i.to_le_bytes());
                }
            } else {
                for i in 0..80u32 {
                    let got = proc.recv(&world, Src::Rank(0), Tag::Value(3));
                    assert_eq!(
                        u32::from_le_bytes(got.as_slice().try_into().unwrap()),
                        i,
                        "stream overtook under striping ({name})"
                    );
                }
            }
        });
    }
}

#[test]
fn striped_eager_rendezvous_mix_stays_ordered() {
    // Alternate small (immediate), medium (eager), and large (rendezvous)
    // messages on one envelope: the reorder stage sequences Eager and RTS
    // envelopes alike, so matching order must equal send order even though
    // the three protocols complete through different paths.
    let spec = ClusterSpec::new(fabric(Interconnect::Ib, 2), MpiConfig::striped(6), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        let sizes = [8usize, 12 * 1024, 64 * 1024, 8, 64 * 1024, 300, 40 * 1024, 8];
        if proc.rank() == 0 {
            for (i, &n) in sizes.iter().enumerate() {
                let mut data = vec![0u8; n];
                data[0] = i as u8;
                proc.send(&world, 1, 5, &data);
            }
        } else {
            for (i, &n) in sizes.iter().enumerate() {
                let got = proc.recv(&world, Src::Rank(0), Tag::Value(5));
                assert_eq!(got.len(), n, "message {i} truncated");
                assert_eq!(got[0], i as u8, "message {i} out of order");
            }
        }
    });
}

#[test]
fn striped_multithreaded_single_comm_streams() {
    // The tentpole workload: 4 threads per process all hammering ONE
    // communicator (distinct tags), striped across 8 VCIs. Each per-thread
    // stream must stay in order.
    let spec = ClusterSpec::new(fabric(Interconnect::Ib, 2), MpiConfig::striped(8), 4);
    run_ok(spec, |proc, t| {
        let world = proc.comm_world();
        let peer = 1 - proc.rank();
        for i in 0..40u32 {
            let sreq = proc.isend(&world, peer, t as i32, &i.to_le_bytes());
            let got = proc.recv(&world, Src::Rank(peer), Tag::Value(t as i32));
            assert_eq!(u32::from_le_bytes(got.as_slice().try_into().unwrap()), i);
            proc.wait(sreq);
        }
    });
}

#[test]
fn striped_wildcard_receives_stay_legal() {
    // Unlike the §7 envelope hints (which must assert wildcards away to
    // spread one communicator), striping keeps MPI_ANY_SOURCE/ANY_TAG
    // fully legal: with one matching shard ordering is restored before a
    // single engine; with per-source shards the wildcard-epoch protocol
    // serializes matching for the duration of the wildcard.
    for cfg in [MpiConfig::striped(6), MpiConfig::striped_sharded(6)] {
        let spec = ClusterSpec::new(fabric(Interconnect::Ib, 3), cfg, 1);
        run_ok(spec, |proc, _t| {
            let world = proc.comm_world();
            if proc.rank() == 0 {
                let mut seen = [0u8; 3];
                for _ in 0..8 {
                    let got = proc.recv(&world, Src::Any, Tag::Any);
                    let who = got[0] as usize;
                    let k = got[1];
                    assert_eq!(k, seen[who], "stream from {who} overtook under wildcards");
                    seen[who] += 1;
                }
                assert_eq!(seen[1], 4);
                assert_eq!(seen[2], 4);
            } else {
                for k in 0..4u8 {
                    proc.send(&world, 0, k as i32, &[proc.rank() as u8, k]);
                }
            }
        });
    }
}

#[test]
fn wildcard_epoch_torture_across_flips() {
    // The epoch state machine under fire: two sender procs stripe numbered
    // per-thread streams at a receiver whose threads mix concrete and
    // MPI_ANY_SOURCE receives, so the communicator flips into and out of
    // the serialized epoch while traffic (and parked reorder state) is in
    // flight. Assert no message is lost or duplicated and that matching
    // order per (source, tag) stream equals send order — in post order,
    // every stream's payload counter must increment by exactly one
    // wherever that stream's messages land.
    for linger in [0u32, 4] {
        let mut cfg = MpiConfig::striped_sharded(8);
        cfg.wildcard_epoch_linger = linger;
        let stats: Arc<Mutex<Vec<vcmpi::mpi::EpochStats>>> = Arc::new(Mutex::new(Vec::new()));
        let s2 = stats.clone();
        let spec = ClusterSpec::new(fabric(Interconnect::Ib, 3), cfg, 3);
        let bars: Arc<Vec<PBarrier>> =
            Arc::new((0..3).map(|_| PBarrier::new(Backend::Sim, 3)).collect());
        run_ok(spec, move |proc, t| {
            let world = proc.comm_world();
            let per_src: u32 = 24;
            if proc.rank() == 0 {
                let total = (2 * per_src) as usize;
                let mut order: Vec<(usize, u32)> = Vec::new();
                let mut j = 0usize;
                while j < total {
                    let batch = 8.min(total - j);
                    let reqs: Vec<_> = (0..batch)
                        .map(|b| {
                            // Every other post pair is a wildcard: half the
                            // receives cross sources, so epochs stay hot.
                            let src = match (j + b) % 4 {
                                0 => Src::Rank(1),
                                1 => Src::Rank(2),
                                _ => Src::Any,
                            };
                            proc.irecv(&world, src, Tag::Value(t as i32))
                        })
                        .collect();
                    for r in reqs {
                        let data = proc.wait(r).expect("recv payload");
                        let s = data[0] as usize;
                        let k = u32::from_le_bytes(data[1..5].try_into().unwrap());
                        order.push((s, k));
                    }
                    j += batch;
                }
                // Per-stream nonovertaking + exactly-once: in post order,
                // each stream counts 0,1,2,... with no gap or repeat.
                let mut next = [0u32; 3];
                for (s, k) in order {
                    assert_eq!(
                        k, next[s],
                        "stream {s} tag {t} (linger {linger}) lost/duplicated/reordered"
                    );
                    next[s] += 1;
                }
                assert_eq!(next[1], per_src, "stream 1 tag {t} incomplete");
                assert_eq!(next[2], per_src, "stream 2 tag {t} incomplete");
            } else {
                let mut reqs = Vec::new();
                for k in 0..per_src {
                    let mut data = vec![proc.rank() as u8];
                    data.extend_from_slice(&k.to_le_bytes());
                    reqs.push(proc.isend(&world, 0, t as i32, &data));
                }
                proc.waitall(reqs);
            }
            bars[proc.rank()].wait();
            if t == 0 {
                proc.barrier(&world);
                if proc.rank() == 0 {
                    let es = proc.epoch_stats();
                    let (dups, parked) = proc.reorder_stats();
                    assert_eq!(dups, 0, "wire traffic must never look duplicated");
                    assert_eq!(parked, 0, "reorder buffers must drain by quiescence");
                    s2.lock().unwrap().push(es);
                }
            }
            bars[proc.rank()].wait();
        });
        let stats = stats.lock().unwrap();
        assert_eq!(stats.len(), 1);
        let es = stats[0];
        assert!(es.wildcard_posts > 0, "torture must post wildcards");
        assert!(es.flips > 0, "wildcards on a sharded comm must flip epochs");
        if linger == 0 {
            assert_eq!(es.flips, es.unflips, "every epoch must resolve at quiescence");
        } else {
            // Operation-counted hysteresis: the FINAL epoch may stay open
            // if the last wildcard completed with fewer than `linger`
            // operations left in the run (documented `mpi::shard`
            // semantics — an idle serialized epoch is free).
            assert!(
                es.flips - es.unflips <= 1,
                "only the final epoch may linger open (flips {} unflips {})",
                es.flips,
                es.unflips
            );
        }
    }
}

#[test]
fn sharded_concrete_streams_stay_ordered_multithreaded() {
    // Sharded matching without wildcards: 4 threads x 2 procs hammer ONE
    // communicator bidirectionally across 8 VCIs with per-source shards —
    // each per-thread stream must stay in order and no epoch may open.
    let spec =
        ClusterSpec::new(fabric(Interconnect::Ib, 2), MpiConfig::striped_sharded(8), 4);
    run_ok(spec, |proc, t| {
        let world = proc.comm_world();
        let peer = 1 - proc.rank();
        for i in 0..40u32 {
            let sreq = proc.isend(&world, peer, t as i32, &i.to_le_bytes());
            let got = proc.recv(&world, Src::Rank(peer), Tag::Value(t as i32));
            assert_eq!(u32::from_le_bytes(got.as_slice().try_into().unwrap()), i);
            proc.wait(sreq);
        }
        assert_eq!(proc.epoch_stats().flips, 0, "no wildcard -> no epoch");
    });
}

#[test]
fn striped_run_leaves_no_parked_arrivals() {
    // After a quiesced striped run every reorder buffer must be empty and
    // no duplicate sequences may have been seen (the wire never
    // duplicates; the counter exists for malformed traffic).
    let stats: Arc<Mutex<Vec<(u64, usize)>>> = Arc::new(Mutex::new(Vec::new()));
    let s2 = stats.clone();
    let bars: Arc<Vec<PBarrier>> =
        Arc::new((0..2).map(|_| PBarrier::new(Backend::Sim, 2)).collect());
    let spec = ClusterSpec::new(fabric(Interconnect::Opa, 2), MpiConfig::striped(8), 2);
    run_ok(spec, move |proc, t| {
        let world = proc.comm_world();
        let peer = 1 - proc.rank();
        for i in 0..30u32 {
            let sreq = proc.isend(&world, peer, t as i32, &i.to_le_bytes());
            let got = proc.recv(&world, Src::Rank(peer), Tag::Value(t as i32));
            assert_eq!(u32::from_le_bytes(got.as_slice().try_into().unwrap()), i);
            proc.wait(sreq);
        }
        // Both local threads must have drained their inbound streams
        // before reading the stats — a sibling mid-exchange can park
        // arrivals transiently (that is the reorder stage working).
        bars[proc.rank()].wait();
        if t == 0 {
            s2.lock().unwrap().push(proc.reorder_stats());
        }
        bars[proc.rank()].wait();
    });
    let stats = stats.lock().unwrap();
    assert_eq!(stats.len(), 2);
    for &(dups, parked) in stats.iter() {
        assert_eq!(dups, 0, "wire traffic must never be seen as duplicate");
        assert_eq!(parked, 0, "reorder buffers must drain by quiescence");
    }
}

// ---------------------------------------------------------------------
// Per-communicator policy (info keys): mixed striped/ordered comms in
// one process, split groups, shard-anchored allocation, freed-comm
// teardown.
// ---------------------------------------------------------------------

#[test]
fn info_keyed_striping_on_an_unstriped_process() {
    // Process-global striping OFF; ONE communicator opts in via info
    // keys. Nonovertaking must hold on the striped comm, world must stay
    // off the sharded path entirely, and both must interleave cleanly.
    let spec = ClusterSpec::new(fabric(Interconnect::Ib, 2), MpiConfig::optimized(8), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        let hot = proc.comm_dup_with_info(
            &world,
            &Info::new()
                .with("vcmpi_striping", "rr")
                .with("vcmpi_match_shards", "4")
                .with("vcmpi_rx_doorbell", "true"),
        );
        assert_eq!(hot.policy.striping, VciStriping::RoundRobin);
        assert_eq!(hot.policy.match_shards, 4);
        assert!(hot.policy.rx_doorbell);
        assert_eq!(world.policy.striping, VciStriping::Off, "defaults stay off");
        let peer = 1 - proc.rank();
        for i in 0..60u32 {
            let s = proc.isend(&hot, peer, 3, &i.to_le_bytes());
            let got = proc.recv(&hot, Src::Rank(peer), Tag::Value(3));
            assert_eq!(u32::from_le_bytes(got.as_slice().try_into().unwrap()), i);
            proc.wait(s);
            if i % 16 == 0 {
                // Interleave ordered world traffic to prove coexistence.
                let s = proc.isend(&world, peer, 9, &i.to_le_bytes());
                let got = proc.recv(&world, Src::Rank(peer), Tag::Value(9));
                assert_eq!(u32::from_le_bytes(got.as_slice().try_into().unwrap()), i);
                proc.wait(s);
            }
        }
        assert!(proc.has_match_engine(hot.id), "striped comm must own a sharded engine");
        assert!(!proc.has_match_engine(world.id), "world must stay on the per-VCI engines");
        assert_eq!(proc.policy_mismatch_count(), 0, "wire contract held");
        proc.barrier(&world);
        proc.comm_free(hot);
    });
}

#[test]
fn per_comm_policies_inherit_and_override_on_dup() {
    // Dup inherits the parent policy; info keys override per creation.
    let spec =
        ClusterSpec::new(fabric(Interconnect::Opa, 2), MpiConfig::striped_sharded(6), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        assert_eq!(world.policy.striping, VciStriping::RoundRobin);
        assert_eq!(world.policy.match_shards, 8);
        let inherited = proc.comm_dup(&world);
        assert_eq!(*inherited.policy, *world.policy, "plain dup inherits");
        let ordered = proc.comm_dup_with_info(&world, &Info::new().with("vcmpi_striping", "off"));
        assert_eq!(ordered.policy.striping, VciStriping::Off);
        assert_eq!(ordered.policy.match_shards, 8, "unnamed keys inherit");
        // Ordered traffic on a striped-default process stays correct.
        let peer = 1 - proc.rank();
        for i in 0..20u32 {
            let s = proc.isend(&ordered, peer, 5, &i.to_le_bytes());
            let got = proc.recv(&ordered, Src::Rank(peer), Tag::Value(5));
            assert_eq!(u32::from_le_bytes(got.as_slice().try_into().unwrap()), i);
            proc.wait(s);
        }
        assert!(!proc.has_match_engine(ordered.id), "ordered comm never shards");
        proc.barrier(&world);
        proc.comm_free(ordered);
        proc.comm_free(inherited);
    });
}

#[test]
fn comm_split_with_info_builds_disjoint_policy_groups() {
    // 4 procs split into even/odd color groups: the even group stripes
    // via info keys, the odd group stays ordered. Rank math is symmetric
    // and each group's streams stay FIFO.
    let spec = ClusterSpec::new(fabric(Interconnect::Opa, 4), MpiConfig::optimized(6), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        let color = (proc.rank() % 2) as u64;
        let info = if color == 0 {
            Info::new().with("vcmpi_striping", "hash").with("vcmpi_match_shards", "2")
        } else {
            Info::new()
        };
        let sub = proc.comm_split_with_info(&world, color, proc.rank() as u64, &info);
        assert_eq!(sub.size, 2, "two procs per color");
        assert_eq!(sub.rank, proc.rank() / 2, "ranked by key within the color");
        if color == 0 {
            assert_eq!(sub.policy.striping, VciStriping::HashedByRequest);
        } else {
            assert_eq!(sub.policy.striping, VciStriping::Off);
        }
        let peer = 1 - sub.rank;
        for i in 0..30u32 {
            let s = proc.isend(&sub, peer, 4, &i.to_le_bytes());
            let got = proc.recv(&sub, Src::Rank(peer), Tag::Value(4));
            assert_eq!(
                u32::from_le_bytes(got.as_slice().try_into().unwrap()),
                i,
                "split-group stream overtook (color {color})"
            );
            proc.wait(s);
        }
        proc.barrier(&world);
        proc.comm_free(sub);
    });
}

#[test]
fn shard_anchored_alloc_takes_one_vci_lock_per_post() {
    // Satellite proof via the Table-1 counters: a striped receive post
    // allocates its request from the shard-anchored VCI's cache — exactly
    // one VCI lock and one shard lock per post, no request-pool lock once
    // caches are warm, and no shared home-VCI funnel (every post on this
    // fallback-homed comm anchors away from home, so `anchored_allocs`
    // counts them all).
    let spec =
        ClusterSpec::new(fabric(Interconnect::Ib, 3), MpiConfig::striped_sharded(8), 1);
    run_ok(spec, |proc, _t| {
        use vcmpi::mpi::instrument::snapshot;
        let world = proc.comm_world();
        if proc.rank() == 0 {
            // Warm both sources' anchored request caches.
            for src in [1usize, 2] {
                let r = proc.irecv(&world, Src::Rank(src), Tag::Value(7));
                let got = proc.wait(r).expect("warm payload");
                assert_eq!(got[0] as usize, src);
            }
            let base = snapshot();
            let reqs: Vec<_> = (0..10)
                .map(|k| proc.irecv(&world, Src::Rank(1 + k % 2), Tag::Value(7)))
                .collect();
            let d = snapshot() - base;
            assert_eq!(d.vci_locks, 10, "one (anchored) VCI lock per striped post");
            assert_eq!(d.shard_locks, 10, "one shard lock per striped post");
            assert_eq!(d.global_locks, 0);
            assert_eq!(d.request_locks, 0, "warm caches: no pool lock on the post path");
            assert_eq!(d.anchored_allocs, 10, "every post anchored off the home VCI");
            for (k, r) in reqs.into_iter().enumerate() {
                let got = proc.wait(r).expect("payload");
                assert_eq!(got[0] as usize, 1 + k % 2, "stream bound to the wrong source");
            }
        } else {
            for _ in 0..6 {
                proc.send(&world, 0, 7, &[proc.rank() as u8]);
            }
        }
        proc.barrier(&world);
    });
}

#[test]
fn freed_striped_comm_drops_its_engines_and_caches() {
    // Satellite: comm_free must unpin the freed comm's shard engines from
    // the process table and every VCI's match_cache (finalize asserts it;
    // this test also checks the observable table state directly).
    let spec = ClusterSpec::new(fabric(Interconnect::Ib, 2), MpiConfig::optimized(8), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        let peer = 1 - proc.rank();
        for round in 0..3 {
            let hot = proc.comm_dup_with_info(
                &world,
                &Info::new().with("vcmpi_striping", "rr").with("vcmpi_match_shards", "4"),
            );
            for i in 0..20u32 {
                let s = proc.isend(&hot, peer, round, &i.to_le_bytes());
                let got = proc.recv(&hot, Src::Rank(peer), Tag::Value(round));
                assert_eq!(u32::from_le_bytes(got.as_slice().try_into().unwrap()), i);
                proc.wait(s);
            }
            assert!(proc.has_match_engine(hot.id));
            proc.barrier(&world);
            let freed_id = hot.id;
            proc.comm_free(hot);
            assert!(
                !proc.has_match_engine(freed_id),
                "freed comm round {round} left its engine pinned"
            );
        }
    });
}

// ---------------------------------------------------------------------
// Wire robustness: stale/duplicate/malformed control messages.
// ---------------------------------------------------------------------

#[test]
fn duplicate_or_stale_cts_is_dropped_not_fatal() {
    // Regression: a CTS for an unknown rendezvous send used to hit
    // `pending_sends.remove(..).expect(..)` and abort the whole process.
    // It must be dropped with a counted diagnostic, and real traffic must
    // keep flowing afterwards.
    let spec = ClusterSpec::new(fabric(Interconnect::Ib, 2), MpiConfig::optimized(4), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        if proc.rank() == 0 {
            // Forge a CTS that answers a rendezvous rank 1 never started.
            proc.fabric.inject(0, 1, 0, Payload::TwoSided {
                comm_id: 0,
                src_rank: 0,
                dst_rank: 1,
                tag: 0,
                seq: 0,
                stripe_home: None,
                protocol: P2pProtocol::Cts { send_handle: 0xDEAD_BEEF, recv_handle: 7 },
                needs_ack: false,
                data: Vec::new(),
            });
            // Tell rank 1 the forgery is on the wire, then run a normal
            // exchange over the same VCI to prove the engine survived.
            proc.send(&world, 1, 9, &[]);
            let got = proc.recv(&world, Src::Rank(1), Tag::Value(10));
            assert_eq!(got, b"alive");
        } else {
            proc.recv(&world, Src::Rank(0), Tag::Value(9));
            while proc.stale_ctrl_drop_count() == 0 {
                proc.progress_for_request(0);
            }
            proc.send(&world, 0, 10, b"alive");
        }
    });
}

#[test]
fn malformed_control_messages_are_dropped_not_fatal() {
    // Acceptance: no expect/unwrap panic reachable from wire-message
    // handling. Throw a battery of malformed control messages at rank 1:
    // out-of-range request handles, an unregistered RMA window, an
    // out-of-bounds RMA offset, and an undersized fetch-op operand.
    let spec = ClusterSpec::new(fabric(Interconnect::Opa, 2), MpiConfig::optimized(4), 1);
    run_ok(spec, |proc, _t| {
        let world = proc.comm_world();
        let win = proc.win_create(&world, 64);
        if proc.rank() == 0 {
            let forged: Vec<Payload> = vec![
                Payload::SendAck { send_handle: u64::MAX },
                Payload::TwoSided {
                    comm_id: 0,
                    src_rank: 0,
                    dst_rank: 1,
                    tag: 0,
                    seq: 0,
                    stripe_home: None,
                    protocol: P2pProtocol::Data { recv_handle: u64::MAX },
                    needs_ack: false,
                    data: vec![1, 2, 3],
                },
                Payload::RmaPut {
                    win: 0xFFFF,
                    offset: 0,
                    data: vec![0; 8],
                    flush_handle: 1,
                    lane: None,
                },
                Payload::RmaPut {
                    win: win.id,
                    offset: 60,
                    data: vec![0; 32],
                    flush_handle: 2,
                    lane: Some(9999), // striped marker on a bad span still just drops
                },
                Payload::RmaGetReq {
                    win: win.id,
                    offset: 60,
                    len: 32,
                    get_handle: 3,
                    lane: Some(9999), // striped get on a bad span drops too
                },
                Payload::RmaFetchOp {
                    win: win.id,
                    offset: 0,
                    operand: vec![1, 2],
                    op: vcmpi::fabric::AccOp::SumU64,
                    fetch_handle: 4,
                },
            ];
            let n = forged.len() as u64;
            for p in forged {
                proc.fabric.inject(0, 1, 0, p);
            }
            proc.send(&world, 1, 9, &n.to_le_bytes());
            let got = proc.recv(&world, Src::Rank(1), Tag::Value(10));
            assert_eq!(got, b"survived");
        } else {
            let n = proc.recv(&world, Src::Rank(0), Tag::Value(9));
            let n = u64::from_le_bytes(n.as_slice().try_into().unwrap());
            while proc.stale_ctrl_drop_count() < n {
                proc.progress_for_request(0);
            }
            proc.send(&world, 0, 10, b"survived");
        }
        proc.barrier(&world);
        proc.win_free(&world, win);
    });
}
