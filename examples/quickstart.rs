//! Quickstart: the vcmpi public API in one file.
//!
//! Runs on the deterministic DES backend (no hardware needed): builds a
//! 2-node cluster, exchanges messages, uses RMA, then compares the
//! message rate of the optimized multi-VCI library against the
//! global-lock baseline — the paper's headline effect.
//!
//!     cargo run --release --example quickstart

use vcmpi::bench::{message_rate, Mode, RateParams};
use vcmpi::fabric::{FabricConfig, Interconnect};
use vcmpi::mpi::{run_cluster, ClusterSpec, MpiConfig, Src, Tag};

fn main() {
    // --- 1. A two-node hello-world over the simulated Omni-Path fabric ---
    let spec = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Opa,
            nodes: 2,
            procs_per_node: 1,
            max_contexts_per_node: 64,
        },
        MpiConfig::optimized(4),
        1, // threads per process
    );
    let report = run_cluster(spec, |proc, _thread| {
        let world = proc.comm_world();
        if proc.rank() == 0 {
            proc.send(&world, 1, 42, b"hello, vci world");
            let reply = proc.recv(&world, Src::Rank(1), Tag::Value(43));
            println!("rank 0 got reply: {}", String::from_utf8_lossy(&reply));
        } else {
            let msg = proc.recv(&world, Src::Rank(0), Tag::Value(42));
            println!("rank 1 got: {}", String::from_utf8_lossy(&msg));
            proc.send(&world, 0, 43, b"hi back");
        }
        // One-sided: expose a window, put into the peer.
        let win = proc.win_create(&world, 1024);
        let peer = 1 - proc.rank();
        proc.put(&win, peer, 0, &[proc.rank() as u8 + 1; 16]);
        proc.win_flush(&win);
        proc.barrier(&world);
        let got = win.read_local(0, 16);
        println!("rank {} window now holds {:?}...", proc.rank(), &got[..4]);
        proc.win_free(&world, win);
    });
    println!(
        "cluster run: {:?} in {} of virtual time\n",
        report.outcome,
        vcmpi::sim::fmt_ns(report.time_ns)
    );

    // --- 2. The paper's headline: multi-VCI vs the global-lock baseline ---
    println!("8-byte MPI_Isend aggregate message rate, 8 threads:");
    for (label, mode) in [
        ("MPI everywhere           ", Mode::Everywhere),
        ("MPI+threads (global lock)", Mode::SerCommOrig),
        ("MPI+threads (multi-VCI)  ", Mode::ParCommVcis),
        ("MPI+threads (endpoints)  ", Mode::Endpoints),
    ] {
        let rate = message_rate(RateParams {
            mode,
            threads: 8,
            msgs_per_core: 1024,
            ..Default::default()
        });
        println!("  {label}  {:>8.2} Mmsg/s", rate / 1e6);
    }
}
