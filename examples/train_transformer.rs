//! End-to-end validation driver (DESIGN.md §7): data-parallel training of
//! the AOT-compiled transformer with gradient allreduce over vcmpi.
//!
//! All three layers compose here: the Pallas/JAX model was lowered at
//! build time (`make artifacts`), this binary executes it through PJRT,
//! and every gradient byte crosses the vcmpi library the paper builds.
//!
//!     make artifacts && cargo run --release --example train_transformer -- \
//!         [--steps 300] [--workers 2] [--buckets 4] [--lr 0.2]
//!
//! The loss curve is printed and the run is recorded in EXPERIMENTS.md.

use vcmpi::coordinator::{train, TrainConfig};

fn arg(args: &[String], key: &str, default: usize) -> usize {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let cfg = TrainConfig {
        steps: arg(&args, "--steps", 300),
        workers: arg(&args, "--workers", 2),
        buckets: arg(&args, "--buckets", 4),
        lr: arg(&args, "--lr-milli", 350) as f32 / 1000.0,
        log_every: 20,
        ..Default::default()
    };
    println!(
        "training: {} workers, {} steps, {} gradient buckets (1 comm each), lr={}",
        cfg.workers, cfg.steps, cfg.buckets, cfg.lr
    );
    let t0 = std::time::Instant::now();
    let r = train(cfg)?;
    let secs = t0.elapsed().as_secs_f64();
    println!("\nparams:          {}", r.params);
    println!("first loss:      {:.4}", r.first_loss);
    println!("final loss:      {:.4}", r.final_loss);
    println!("step time:       {:.1} ms (allreduce {:.1} ms)", r.step_ms, r.allreduce_ms);
    println!("wallclock:       {secs:.1}s");
    // Compact loss curve (every 10th step).
    println!("\nloss curve (every 10 steps):");
    for (i, chunk) in r.losses.chunks(10).enumerate() {
        println!("  step {:4}: {:.4}", i * 10, chunk[0]);
    }
    // ln(512) ~ 6.24 is the uniform-prediction floor; a clear, sustained
    // drop demonstrates the three layers compose (the affine-chain corpus
    // saturates much lower with more steps).
    anyhow::ensure!(
        r.final_loss < r.first_loss - 0.4,
        "training failed to reduce loss: {} -> {}",
        r.first_loss,
        r.final_loss
    );
    println!("\nloss reduced by {:.1}x — all three layers compose.",
        r.first_loss / r.final_loss);
    Ok(())
}
