//! EBMS application driver (paper §6.2):
//!  1. regenerates Figs. 24/25 (DES) — remote-fetch times across band
//!     sizes on both interconnects, with the Get/Flush split, and
//!  2. runs the real energy-band loop natively: the cross-section band is
//!     fetched over vcmpi RMA and particles are attenuated by the
//!     AOT-compiled Pallas kernel (PJRT).
//!
//!     make artifacts && cargo run --release --example ebms_fetch

use std::sync::Arc;

use vcmpi::apps::ebms::{fig24, fig25};
use vcmpi::fabric::{FabricConfig, Interconnect};
use vcmpi::mpi::{run_cluster, ClusterSpec, MpiConfig};
use vcmpi::platform::Backend;
use vcmpi::runtime::{SharedRuntime, Tensor};

fn main() -> anyhow::Result<()> {
    println!("Fig. 24 — EBMS remote-fetch time (4 nodes x 16 cores):");
    fig24(&[16 * 1024, 64 * 1024], 3).print();
    println!("\nFig. 25 — Get vs Flush split on the software-RMA fabric:");
    fig25(&[16 * 1024, 64 * 1024], 3).print();

    println!("\nnative band fetch + Pallas attenuation:");
    let rt = Arc::new(SharedRuntime::open("artifacts")?);
    rt.warm("ebms_band")?;
    const BAND: usize = 4096; // f32 cross sections
    const PARTICLES: usize = 2048;
    let mut spec = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Ib,
            nodes: 2,
            procs_per_node: 1,
            max_contexts_per_node: 16,
        },
        MpiConfig::optimized(4),
        1,
    );
    spec.backend = Backend::Native;
    let rt2 = rt.clone();
    let r = run_cluster(spec, move |proc, _t| {
        let world = proc.comm_world();
        let win = proc.win_create(&world, BAND * 4);
        if proc.rank() == 1 {
            // The band server: sigma = 0.5 for every energy bin.
            let xs: Vec<u8> =
                std::iter::repeat(0.5f32.to_le_bytes()).take(BAND).flatten().collect();
            win.write_local(0, &xs);
        }
        proc.barrier(&world);
        if proc.rank() == 0 {
            let h = proc.get(&win, 1, 0, BAND * 4);
            proc.win_flush(&win);
            let xs_bytes = proc.get_data(&win, h);
            let xs: Vec<f32> = xs_bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            let idx: Vec<i32> = (0..PARTICLES as i32).map(|i| i % BAND as i32).collect();
            let dist = vec![2.0f32; PARTICLES];
            let out = rt2
                .run("ebms_band", &[
                    Tensor::f32(&[BAND], xs),
                    Tensor::i32(&[PARTICLES], idx),
                    Tensor::f32(&[PARTICLES], dist),
                ])
                .expect("ebms_band");
            let att = out[0].as_f32();
            let want = (-1.0f32).exp(); // exp(-0.5 * 2.0)
            assert!(att.iter().all(|&x| (x - want).abs() < 1e-5));
            println!(
                "  attenuation[0] = {:.6} (want {want:.6}) — fetch + kernel verified",
                att[0]
            );
        }
        proc.barrier(&world);
        proc.win_free(&world, win);
    });
    anyhow::ensure!(r.outcome == vcmpi::sim::SimOutcome::Completed, "{:?}", r.outcome);
    Ok(())
}
