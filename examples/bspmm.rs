//! BSPMM application driver (paper §6.3):
//!  1. regenerates Fig. 27 (DES) — Get/Accumulate init+flush times for
//!     MPI everywhere / par_comm+vcis / endpoints / the
//!     accumulate_ordering=none hint, and
//!  2. runs the real get-compute-update loop natively: tiles fetched over
//!     vcmpi RMA, multiplied by the AOT-compiled Pallas MAC kernel (PJRT),
//!     results accumulated back — with a numerical check.
//!
//!     make artifacts && cargo run --release --example bspmm

use std::sync::Arc;

use vcmpi::apps::bspmm::fig27;
use vcmpi::fabric::{AccOp, FabricConfig, Interconnect};
use vcmpi::mpi::{run_cluster, ClusterSpec, MpiConfig};
use vcmpi::platform::Backend;
use vcmpi::runtime::{SharedRuntime, Tensor};

fn main() -> anyhow::Result<()> {
    println!("Fig. 27 — BSPMM phase times (4 nodes x 16 cores):");
    fig27(&[128, 256], 2).print();

    println!("\nnative get-compute-update with the Pallas MAC kernel:");
    let rt = Arc::new(SharedRuntime::open("artifacts")?);
    rt.warm("bspmm_tile")?;
    const D: usize = 128;
    let tile_bytes = D * D * 4;
    let mut spec = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Ib,
            nodes: 2,
            procs_per_node: 1,
            max_contexts_per_node: 16,
        },
        MpiConfig::optimized(4),
        1,
    );
    spec.backend = Backend::Native;
    let rt2 = rt.clone();
    let r = run_cluster(spec, move |proc, _t| {
        let world = proc.comm_world();
        // Rank 1 hosts A (all 2.0) and B (all 0.5); rank 0 computes and
        // accumulates into rank 1's C window.
        let a_win = proc.win_create(&world, tile_bytes);
        let b_win = proc.win_create(&world, tile_bytes);
        let c_win = proc.win_create(&world, tile_bytes);
        if proc.rank() == 1 {
            let a: Vec<u8> = std::iter::repeat(2.0f32.to_le_bytes())
                .take(D * D)
                .flatten()
                .collect();
            let b: Vec<u8> = std::iter::repeat(0.5f32.to_le_bytes())
                .take(D * D)
                .flatten()
                .collect();
            a_win.write_local(0, &a);
            b_win.write_local(0, &b);
        }
        proc.barrier(&world);
        if proc.rank() == 0 {
            // get -> compute (PJRT Pallas kernel) -> update.
            let ha = proc.get(&a_win, 1, 0, tile_bytes);
            let hb = proc.get(&b_win, 1, 0, tile_bytes);
            proc.win_flush(&a_win);
            proc.win_flush(&b_win);
            let a_bytes = proc.get_data(&a_win, ha);
            let b_bytes = proc.get_data(&b_win, hb);
            let to_f32 = |v: &[u8]| -> Vec<f32> {
                v.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
            };
            let out = rt2
                .run("bspmm_tile", &[
                    Tensor::f32(&[D, D], to_f32(&a_bytes)),
                    Tensor::f32(&[D, D], to_f32(&b_bytes)),
                    Tensor::f32(&[D, D], vec![0.0; D * D]),
                ])
                .expect("bspmm_tile");
            let c = out[0].as_f32();
            // Every element: sum_k 2.0*0.5 = 128.
            assert!(c.iter().all(|&x| (x - 128.0).abs() < 1e-3), "tile MAC wrong");
            let c_bytes: Vec<u8> = c.iter().flat_map(|f| f.to_le_bytes()).collect();
            proc.accumulate(&c_win, 1, 0, &c_bytes, AccOp::Replace);
            proc.win_flush(&c_win);
            proc.send(&world, 1, 1, &[]);
        } else {
            let _ = proc.recv(&world, vcmpi::mpi::Src::Rank(0), vcmpi::mpi::Tag::Value(1));
            let c = c_win.read_local(0, 4);
            let v = f32::from_le_bytes(c.try_into().unwrap());
            assert!((v - 128.0).abs() < 1e-3, "accumulated C wrong: {v}");
            println!("  C[0,0] = {v} (want 128.0) — get-compute-update verified");
        }
        proc.barrier(&world);
        for w in [a_win, b_win, c_win] {
            proc.win_free(&world, w);
        }
    });
    anyhow::ensure!(r.outcome == vcmpi::sim::SimOutcome::Completed, "{:?}", r.outcome);
    Ok(())
}
