//! Stencil application driver (paper §6.1):
//!  1. regenerates Fig. 22 — halo-exchange time per iteration across mesh
//!     sizes for MPI everywhere / par_comm / endpoints (DES backend), and
//!  2. runs a real 5-point Jacobi sweep whose block updates execute the
//!     AOT-compiled Pallas stencil kernel via PJRT, halos exchanged over
//!     vcmpi (native backend) — the full three-layer composition.
//!
//!     make artifacts && cargo run --release --example stencil_halo

use std::sync::{Arc, Mutex};

use vcmpi::apps::stencil::fig22;
use vcmpi::fabric::{FabricConfig, Interconnect};
use vcmpi::mpi::{run_cluster, ClusterSpec, MpiConfig, Src, Tag};
use vcmpi::platform::Backend;
use vcmpi::runtime::{SharedRuntime, Tensor};

fn main() -> anyhow::Result<()> {
    // --- Fig. 22 (communication only, DES) ---
    println!("Fig. 22 — halo time per iteration (9 nodes x 16 cores):");
    fig22(&[1536, 3072], 3).print();

    // --- Native: 2 ranks, each owns a 64x64 block, PJRT compute ---
    println!("\nnative Jacobi sweep with PJRT stencil compute:");
    let rt = Arc::new(SharedRuntime::open("artifacts")?);
    rt.warm("stencil_block")?;
    let mut spec = ClusterSpec::new(
        FabricConfig {
            interconnect: Interconnect::Ib,
            nodes: 2,
            procs_per_node: 1,
            max_contexts_per_node: 16,
        },
        MpiConfig::optimized(4),
        1,
    );
    spec.backend = Backend::Native;
    let residuals: Arc<Mutex<Vec<f32>>> = Arc::new(Mutex::new(Vec::new()));
    let res2 = residuals.clone();
    let rt2 = rt.clone();
    let r = run_cluster(spec, move |proc, _t| {
        let world = proc.comm_world();
        let peer = 1 - proc.rank();
        const H: usize = 64;
        const WP: usize = 66;
        // Interior starts hot on rank 0, cold on rank 1.
        let mut u = vec![if proc.rank() == 0 { 1.0f32 } else { 0.0 }; WP * WP];
        for it in 0..5 {
            // Exchange the boundary column with the peer (1-D split).
            let my_col: Vec<u8> = (1..=H)
                .flat_map(|i| {
                    let x = if proc.rank() == 0 { u[i * WP + H] } else { u[i * WP + 1] };
                    x.to_le_bytes()
                })
                .collect();
            let sreq = proc.isend(&world, peer, it, &my_col);
            let got = proc.recv(&world, Src::Rank(peer), Tag::Value(it));
            proc.wait(sreq);
            for (i, chunk) in got.chunks_exact(4).enumerate() {
                let v = f32::from_le_bytes(chunk.try_into().unwrap());
                let col = if proc.rank() == 0 { H + 1 } else { 0 };
                u[(i + 1) * WP + col] = v;
            }
            // PJRT: one Pallas stencil block update.
            let out = rt2
                .run("stencil_block", &[Tensor::f32(&[WP, WP], u.clone())])
                .expect("stencil_block");
            let upd = out[0].as_f32();
            let mut resid = 0.0f32;
            for i in 0..H {
                for j in 0..H {
                    let d = upd[i * H + j];
                    resid += d * d;
                    u[(i + 1) * WP + (j + 1)] += 0.5 * d; // damped Jacobi
                }
            }
            if proc.rank() == 0 {
                res2.lock().unwrap().push(resid.sqrt());
            }
        }
    });
    anyhow::ensure!(r.outcome == vcmpi::sim::SimOutcome::Completed, "{:?}", r.outcome);
    let res = residuals.lock().unwrap();
    for (it, r) in res.iter().enumerate() {
        println!("  iter {it}: residual {r:.4}");
    }
    anyhow::ensure!(
        res.last().unwrap() < res.first().unwrap(),
        "Jacobi sweep must reduce the residual"
    );
    println!("residual decreased — kernels + halo exchange compose.");
    Ok(())
}
