"""AOT lowering: every compute graph the rust runtime executes, as HLO TEXT.

HLO *text*, NOT serialized protos: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md and gen_hlo.py.

Usage:  python -m compile.aot --out-dir ../artifacts

Outputs (per graph): <name>.hlo.txt plus a manifest.json describing
argument/result shapes for the rust loader.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .model import ModelConfig


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_of(x):
    return {"shape": list(x.shape), "dtype": x.dtype.name}


def lower_entry(fn, example_args, name):
    """Lower `fn` (tupled results) and return (hlo_text, manifest entry)."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    out = jax.eval_shape(fn, *example_args)
    outs = out if isinstance(out, tuple) else (out,)
    entry = {
        "name": name,
        "inputs": [_spec_of(a) for a in example_args],
        "outputs": [_spec_of(o) for o in outs],
    }
    return text, entry


def build_all(out_dir: str, cfg: ModelConfig) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"model_config": dataclass_dict(cfg), "entries": []}

    f32 = jnp.float32
    i32 = jnp.int32
    P = model.param_count(cfg)
    spec = jax.ShapeDtypeStruct

    graphs = [
        (
            "train_grad_step",
            lambda p, t: model.grad_step(cfg, p, t),
            (spec((P,), f32), spec((cfg.batch, cfg.seq), i32)),
        ),
        (
            "train_sgd_step",
            lambda p, g, lr: (model.sgd_step(p, g, lr),),
            (spec((P,), f32), spec((P,), f32), spec((), f32)),
        ),
        (
            "train_loss",
            lambda p, t: (model.loss_fn(cfg, p, t),),
            (spec((P,), f32), spec((cfg.batch, cfg.seq), i32)),
        ),
        (
            "bspmm_tile",
            lambda a, b, c: (model.bspmm_tile_step(a, b, c),),
            (spec((128, 128), f32), spec((128, 128), f32), spec((128, 128), f32)),
        ),
        (
            "stencil_block",
            lambda u: (model.stencil_block_step(u),),
            (spec((66, 66), f32),),
        ),
        (
            "ebms_band",
            lambda xs, idx, d: (model.ebms_band_step(xs, idx, d),),
            (spec((4096,), f32), spec((2048,), i32), spec((2048,), f32)),
        ),
    ]

    for name, fn, args in graphs:
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text, entry = lower_entry(fn, args, name)
        with open(path, "w") as f:
            f.write(text)
        entry["file"] = f"{name}.hlo.txt"
        manifest["entries"].append(entry)
        print(f"  wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # TSV twin for the rust loader (no JSON parser in the offline crate set):
    #   name \t file \t in:shape:dtype;... \t out:shape:dtype;...
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        mc = manifest["model_config"]
        f.write(
            "#model_config\t"
            + "\t".join(f"{k}={v}" for k, v in sorted(mc.items()))
            + "\n"
        )
        for e in manifest["entries"]:
            ins = ";".join(
                "x".join(map(str, s["shape"])) + ":" + s["dtype"] for s in e["inputs"]
            )
            outs = ";".join(
                "x".join(map(str, s["shape"])) + ":" + s["dtype"] for s in e["outputs"]
            )
            f.write(f"{e['name']}\t{e['file']}\t{ins}\t{outs}\n")
    print(f"  wrote {os.path.join(out_dir, 'manifest.json')} (+ .tsv)")
    return manifest


def dataclass_dict(cfg: ModelConfig):
    return {
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_head": cfg.n_head,
        "n_layer": cfg.n_layer,
        "d_ff": cfg.d_ff,
        "seq": cfg.seq,
        "batch": cfg.batch,
        "param_count": model.param_count(cfg),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-layer", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    cfg = ModelConfig(
        d_model=args.d_model, n_layer=args.n_layer, seq=args.seq, batch=args.batch
    )
    print(f"AOT-lowering (params={model.param_count(cfg):,}) -> {args.out_dir}")
    build_all(args.out_dir, cfg)


if __name__ == "__main__":
    main()
