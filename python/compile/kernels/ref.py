"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every kernel in this package must match its oracle to float tolerance;
`python/tests/` enforces this with fixed cases plus hypothesis sweeps.
"""

import jax.numpy as jnp


def bspmm_tile_ref(a, b, c_acc):
    """One BSPMM work unit: C_acc += A @ B (f32 tiles)."""
    return c_acc + jnp.matmul(a, b, preferred_element_type=jnp.float32)


def stencil_ref(u):
    """5-point stencil over a padded (H+2, W+2) grid -> (H, W).

    out = 0.25 * (N + S + E + W) - center   (Jacobi-style update)
    """
    center = u[1:-1, 1:-1]
    north = u[:-2, 1:-1]
    south = u[2:, 1:-1]
    west = u[1:-1, :-2]
    east = u[1:-1, 2:]
    return 0.25 * (north + south + east + west) - center


def ebms_attenuate_ref(xs_band, idx, dist):
    """EBMS: per-particle attenuation through one energy band.

    out[n] = exp(-xs_band[idx[n]] * dist[n])
    """
    return jnp.exp(-xs_band[idx] * dist)
