"""L1 Pallas kernel: BSPMM tile multiply-accumulate (NWChem §6.3).

The get-compute-update worker's compute hot spot: C_acc += A @ B over
dense f32 tiles fetched via MPI_Get.

TPU mapping (DESIGN.md §Hardware-Adaptation / §8):
  * Tiles are MXU-shaped: the contraction runs over (TM, TK) x (TK, TN)
    blocks with TM = TN = TK = 128 by default — one MXU systolic pass per
    block pair, f32 accumulate.
  * BlockSpec walks K in `grid` steps so each VMEM residency holds one
    (TM, TK) A-block, one (TK, TN) B-block, and the (TM, TN) accumulator:
    3 * 128*128*4 B = 192 KiB << 16 MiB VMEM.
  * `interpret=True` everywhere in this environment: the CPU PJRT plugin
    cannot execute Mosaic custom-calls; real-TPU numbers are estimated in
    DESIGN.md §8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE = 128


def _mac_kernel(a_ref, b_ref, c_ref, o_ref, *, k_steps):
    """Grid point (i, j, k): o[i,j] (+)= a[i,k] @ b[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )
    del k_steps


def bspmm_tile(a, b, c_acc, *, block=DEFAULT_TILE):
    """C_acc + A @ B via a K-stepped Pallas grid.

    a: (M, K) f32; b: (K, N) f32; c_acc: (M, N) f32. M, K, N must be
    multiples of `block`.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % block == 0 and n % block == 0 and k % block == 0, (
        f"dims ({m},{k},{n}) must be multiples of {block}"
    )
    grid = (m // block, n // block, k // block)
    kernel = functools.partial(_mac_kernel, k_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, kk: (i, kk)),  # A
            pl.BlockSpec((block, block), lambda i, j, kk: (kk, j)),  # B
            pl.BlockSpec((block, block), lambda i, j, kk: (i, j)),   # C_acc
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU-PJRT execution; see module docstring
    )(a, b, c_acc)


def vmem_bytes(block=DEFAULT_TILE):
    """Estimated VMEM residency of one grid step (A + B + C blocks)."""
    return 3 * block * block * 4
