"""L1 Pallas kernel: 5-point stencil update (paper §6.1's workload).

Each MPI+threads worker owns a (H, W) sub-block (halo exchanged through
vcmpi); the local update is this kernel over the halo-padded (H+2, W+2)
input.

TPU mapping (DESIGN.md §8): element-wise VPU work, no MXU. The padded
block is held in VMEM in full — the default per-thread block in the paper's
stencil runs is at most (514, 514) f32 ~ 1.06 MiB << 16 MiB, so a single
VMEM residency with shifted-slice reads is the right schedule (halo bands
would add copies without saving memory at these sizes). `interpret=True`
as everywhere in this build (see bspmm.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stencil_kernel(u_ref, o_ref):
    u = u_ref[...]
    center = u[1:-1, 1:-1]
    north = u[:-2, 1:-1]
    south = u[2:, 1:-1]
    west = u[1:-1, :-2]
    east = u[1:-1, 2:]
    o_ref[...] = 0.25 * (north + south + east + west) - center


def stencil_step(u_padded):
    """Apply the 5-point update to a halo-padded (H+2, W+2) f32 grid,
    returning the (H, W) interior update."""
    hp, wp = u_padded.shape
    h, w = hp - 2, wp - 2
    assert h >= 1 and w >= 1
    return pl.pallas_call(
        _stencil_kernel,
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(u_padded)


def vmem_bytes(h, w):
    """Estimated VMEM residency: padded input + output."""
    return ((h + 2) * (w + 2) + h * w) * 4
