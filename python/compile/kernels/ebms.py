"""L1 Pallas kernel: EBMS per-particle attenuation (paper §6.2's compute).

After a worker fetches one energy band of the cross-section table via
MPI_Get, it tracks its share of particles through that band:
out[n] = exp(-xs_band[idx[n]] * dist[n]).

TPU mapping (DESIGN.md §8): the band (<= 256 KiB) stays VMEM-resident
across the whole particle stream; particles stream through in blocks.
Gather from the band + VPU transcendental per element. `interpret=True`
as everywhere in this build (see bspmm.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ebms_kernel(xs_ref, idx_ref, dist_ref, o_ref):
    xs = xs_ref[...]
    idx = idx_ref[...]
    dist = dist_ref[...]
    sigma = xs[idx]
    o_ref[...] = jnp.exp(-sigma * dist)


def ebms_attenuate(xs_band, idx, dist, *, particle_block=1024):
    """Attenuation of `len(idx)` particles through one band.

    xs_band: (B,) f32 cross-sections; idx: (N,) i32 band indices in [0, B);
    dist: (N,) f32 path lengths. N must be a multiple of `particle_block`
    (pick particle_block = N for a single block).
    """
    (n,) = idx.shape
    if n % particle_block != 0:
        particle_block = n
    grid = (n // particle_block,)
    return pl.pallas_call(
        functools.partial(_ebms_kernel),
        grid=grid,
        in_specs=[
            # The whole band is resident for every particle block.
            pl.BlockSpec(xs_band.shape, lambda b: (0,)),
            pl.BlockSpec((particle_block,), lambda b: (b,)),
            pl.BlockSpec((particle_block,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((particle_block,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(xs_band, idx, dist)


def vmem_bytes(band, particle_block=1024):
    """Estimated VMEM residency: band + one particle block (idx/dist/out)."""
    return band * 4 + 3 * particle_block * 4
