"""L2: JAX compute graphs compiled AOT for the rust runtime.

Two families:

1. The dist-train tie-in (DESIGN.md §7): a small GPT-style causal LM with
   **flat f32 parameters** so the rust coordinator can bucket one vector
   into per-communicator allreduce chunks:
     * grad_step(flat_params, tokens) -> (loss, flat_grads)
     * sgd_step(flat_params, flat_grads, lr)   -> flat_params'
   The transformer blocks use plain jnp (XLA-fused) — interpret-mode
   Pallas in the training hot loop would be prohibitively slow on CPU; a
   Pallas-MLP variant exists for correctness tests only.

2. The paper's application compute (called from the rust app drivers):
     * bspmm_tile_step — Pallas tile MAC (kernels/bspmm.py)
     * stencil_block_step — Pallas 5-point update (kernels/stencil.py)
     * ebms_band_step — Pallas attenuation (kernels/ebms.py)
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import bspmm as bspmm_k
from .kernels import ebms as ebms_k
from .kernels import stencil as stencil_k


# ---------------------------------------------------------------------------
# Transformer (flat-parameter causal LM)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    d_model: int = 256
    n_head: int = 4
    n_layer: int = 4
    d_ff: int = 1024
    seq: int = 64
    batch: int = 8

    @property
    def head_dim(self):
        return self.d_model // self.n_head


def param_shapes(cfg: ModelConfig):
    """Ordered (name, shape) list defining the flat-parameter layout."""
    shapes = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq, cfg.d_model)),
    ]
    for layer in range(cfg.n_layer):
        p = f"l{layer}."
        shapes += [
            (p + "ln1_g", (cfg.d_model,)),
            (p + "ln1_b", (cfg.d_model,)),
            (p + "wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2_g", (cfg.d_model,)),
            (p + "ln2_b", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
        ]
    shapes += [("lnf_g", (cfg.d_model,)), ("lnf_b", (cfg.d_model,))]
    return shapes


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_shapes(cfg))


def unflatten(cfg: ModelConfig, flat):
    """Split the flat vector into the named parameter dict."""
    params = {}
    off = 0
    for name, shape in param_shapes(cfg):
        n = 1
        for d in shape:
            n *= d
        params[name] = flat[off : off + n].reshape(shape)
        off += n
    return params


def init_flat_params(cfg: ModelConfig, key) -> jax.Array:
    """Scaled-normal init, flattened in layout order."""
    chunks = []
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            chunks.append(jnp.ones(shape, jnp.float32).reshape(-1))
        elif name.endswith(("_b",)):
            chunks.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        else:
            fan_in = shape[0]
            w = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(
                jnp.float32(fan_in)
            )
            chunks.append(w.reshape(-1))
    return jnp.concatenate(chunks)


def _layernorm(x, g, b):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _attn(cfg: ModelConfig, x, wqkv, wo):
    b, t, d = x.shape
    qkv = x @ wqkv  # (b, t, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):
        return z.reshape(b, t, cfg.n_head, cfg.head_dim).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    scores = q @ k.transpose(0, 1, 3, 2) / jnp.sqrt(jnp.float32(cfg.head_dim))
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = (probs @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ wo


def forward(cfg: ModelConfig, flat_params, tokens):
    """Causal-LM logits (B, T, vocab)."""
    p = unflatten(cfg, flat_params)
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :, :]
    for layer in range(cfg.n_layer):
        pre = f"l{layer}."
        h = _layernorm(x, p[pre + "ln1_g"], p[pre + "ln1_b"])
        x = x + _attn(cfg, h, p[pre + "wqkv"], p[pre + "wo"])
        h = _layernorm(x, p[pre + "ln2_g"], p[pre + "ln2_b"])
        x = x + jax.nn.gelu(h @ p[pre + "w1"]) @ p[pre + "w2"]
    x = _layernorm(x, p["lnf_g"], p["lnf_b"])
    # Weight-tied readout.
    return x @ p["tok_emb"].T


def loss_fn(cfg: ModelConfig, flat_params, tokens):
    """Next-token cross-entropy over (B, T) token ids."""
    logits = forward(cfg, flat_params, tokens)  # (B, T, V)
    targets = tokens[:, 1:]
    logits = logits[:, :-1, :]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -ll.mean()


def grad_step(cfg: ModelConfig, flat_params, tokens):
    """One worker's contribution: (loss, flat_grads)."""
    loss, grads = jax.value_and_grad(functools.partial(loss_fn, cfg))(
        flat_params, tokens
    )
    return loss, grads


def sgd_step(flat_params, flat_grads, lr):
    """Plain SGD on the flat vector (lr is a scalar array)."""
    return flat_params - lr * flat_grads


# ---------------------------------------------------------------------------
# Application compute graphs (wrap the Pallas kernels)
# ---------------------------------------------------------------------------


def bspmm_tile_step(a, b, c_acc):
    """One BSPMM work unit (Pallas inside)."""
    return bspmm_k.bspmm_tile(a, b, c_acc)


def stencil_block_step(u_padded):
    """One stencil block update (Pallas inside)."""
    return stencil_k.stencil_step(u_padded)


def ebms_band_step(xs_band, idx, dist):
    """One EBMS band-tracking step (Pallas inside)."""
    return ebms_k.ebms_attenuate(xs_band, idx, dist)
