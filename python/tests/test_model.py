"""L2 model checks: shapes, loss behavior, gradient sanity, AOT round-trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.model import ModelConfig


CFG = ModelConfig(vocab=64, d_model=32, n_head=2, n_layer=2, d_ff=64, seq=16, batch=2)


@pytest.fixture(scope="module")
def flat_params():
    return model.init_flat_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    return jax.random.randint(jax.random.PRNGKey(1), (CFG.batch, CFG.seq), 0, CFG.vocab)


def test_param_layout_consistent(flat_params):
    assert flat_params.shape == (model.param_count(CFG),)
    p = model.unflatten(CFG, flat_params)
    assert p["tok_emb"].shape == (CFG.vocab, CFG.d_model)
    assert p["l0.wqkv"].shape == (CFG.d_model, 3 * CFG.d_model)
    # Round-trip: reflattening in layout order reproduces the vector.
    reflat = jnp.concatenate(
        [p[name].reshape(-1) for name, _ in model.param_shapes(CFG)]
    )
    np.testing.assert_array_equal(reflat, flat_params)


def test_forward_shape_and_finite(flat_params, tokens):
    logits = model.forward(CFG, flat_params, tokens)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_initial_loss_near_uniform(flat_params, tokens):
    loss = model.loss_fn(CFG, flat_params, tokens)
    uniform = jnp.log(jnp.float32(CFG.vocab))
    assert abs(float(loss) - float(uniform)) < 1.5, (loss, uniform)


def test_causality(flat_params, tokens):
    # Changing a future token must not affect earlier logits.
    logits = model.forward(CFG, flat_params, tokens)
    perturbed = tokens.at[:, -1].set((tokens[:, -1] + 1) % CFG.vocab)
    logits2 = model.forward(CFG, flat_params, perturbed)
    np.testing.assert_allclose(
        logits[:, :-1, :], logits2[:, :-1, :], rtol=1e-5, atol=1e-5
    )


def test_grad_step_reduces_loss(flat_params, tokens):
    loss0, grads = model.grad_step(CFG, flat_params, tokens)
    assert grads.shape == flat_params.shape
    assert bool(jnp.isfinite(grads).all())
    stepped = model.sgd_step(flat_params, grads, jnp.float32(0.1))
    loss1 = model.loss_fn(CFG, stepped, tokens)
    assert float(loss1) < float(loss0), (loss0, loss1)


def test_training_loop_converges_on_fixed_batch():
    cfg = CFG
    params = model.init_flat_params(cfg, jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (cfg.batch, cfg.seq), 0, cfg.vocab)
    step = jax.jit(lambda p, t: model.grad_step(cfg, p, t))
    loss0 = None
    for i in range(30):
        loss, g = step(params, toks)
        if i == 0:
            loss0 = float(loss)
        params = model.sgd_step(params, g, jnp.float32(0.5))
    assert float(loss) < 0.5 * loss0, (loss0, float(loss))


def test_aot_lowering_emits_parsable_hlo(tmp_path):
    from compile import aot

    manifest = aot.build_all(str(tmp_path), CFG)
    assert len(manifest["entries"]) == 6
    for e in manifest["entries"]:
        text = (tmp_path / e["file"]).read_text()
        assert text.startswith("HloModule"), e["file"]
        assert len(text) > 200
    # grad_step signature matches the manifest.
    gs = next(e for e in manifest["entries"] if e["name"] == "train_grad_step")
    P = model.param_count(CFG)
    assert gs["inputs"][0]["shape"] == [P]
    assert gs["outputs"][0]["shape"] == []  # loss scalar
    assert gs["outputs"][1]["shape"] == [P]
