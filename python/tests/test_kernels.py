"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Fixed cases plus hypothesis sweeps over shapes/values.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bspmm, ebms, ref, stencil


def rand(key, shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, lo, hi)


# ---------------------------------------------------------------------------
# BSPMM tile MAC
# ---------------------------------------------------------------------------


class TestBspmm:
    def test_matches_ref_128(self):
        a, b, c = rand(0, (128, 128)), rand(1, (128, 128)), rand(2, (128, 128))
        got = bspmm.bspmm_tile(a, b, c)
        want = ref.bspmm_tile_ref(a, b, c)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_multi_tile_grid(self):
        # 256x384x512: exercises the (i, j, k) grid with k accumulation.
        a, b, c = rand(3, (256, 384)), rand(4, (384, 512)), rand(5, (256, 512))
        got = bspmm.bspmm_tile(a, b, c)
        want = ref.bspmm_tile_ref(a, b, c)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_tiles_passthrough(self):
        a = jnp.zeros((128, 128), jnp.float32)
        b = jnp.zeros((128, 128), jnp.float32)
        c = rand(6, (128, 128))
        np.testing.assert_allclose(bspmm.bspmm_tile(a, b, c), c, rtol=1e-6)

    def test_rejects_ragged_dims(self):
        with pytest.raises(AssertionError):
            bspmm.bspmm_tile(
                jnp.zeros((100, 128)), jnp.zeros((128, 128)), jnp.zeros((100, 128))
            )

    @settings(max_examples=8, deadline=None)
    @given(
        m=st.sampled_from([1, 2, 3]),
        k=st.sampled_from([1, 2]),
        n=st.sampled_from([1, 2]),
        seed=st.integers(0, 2**31 - 1),
        block=st.sampled_from([32, 64]),
    )
    def test_hypothesis_shapes(self, m, k, n, seed, block):
        a = rand(seed, (m * block, k * block))
        b = rand(seed + 1, (k * block, n * block))
        c = rand(seed + 2, (m * block, n * block))
        got = bspmm.bspmm_tile(a, b, c, block=block)
        want = ref.bspmm_tile_ref(a, b, c)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_vmem_estimate_under_budget(self):
        assert bspmm.vmem_bytes(128) < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# Stencil
# ---------------------------------------------------------------------------


class TestStencil:
    def test_matches_ref(self):
        u = rand(7, (66, 66))
        np.testing.assert_allclose(
            stencil.stencil_step(u), ref.stencil_ref(u), rtol=1e-6, atol=1e-6
        )

    def test_constant_field_fixed_point_structure(self):
        # For u == 1 everywhere: update = 0.25*4*1 - 1 = 0.
        u = jnp.ones((34, 34), jnp.float32)
        np.testing.assert_allclose(
            stencil.stencil_step(u), jnp.zeros((32, 32)), atol=1e-7
        )

    @settings(max_examples=8, deadline=None)
    @given(
        h=st.integers(1, 40),
        w=st.integers(1, 40),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, h, w, seed):
        u = rand(seed, (h + 2, w + 2), -10.0, 10.0)
        np.testing.assert_allclose(
            stencil.stencil_step(u), ref.stencil_ref(u), rtol=1e-5, atol=1e-5
        )


# ---------------------------------------------------------------------------
# EBMS attenuation
# ---------------------------------------------------------------------------


class TestEbms:
    def test_matches_ref(self):
        xs = rand(8, (4096,), 0.0, 3.0)
        idx = jax.random.randint(jax.random.PRNGKey(9), (2048,), 0, 4096)
        d = rand(10, (2048,), 0.0, 2.0)
        np.testing.assert_allclose(
            ebms.ebms_attenuate(xs, idx, d),
            ref.ebms_attenuate_ref(xs, idx, d),
            rtol=1e-6,
            atol=1e-6,
        )

    def test_zero_distance_is_unity(self):
        xs = rand(11, (64,), 0.0, 5.0)
        idx = jnp.arange(64, dtype=jnp.int32)
        d = jnp.zeros(64, jnp.float32)
        np.testing.assert_allclose(
            ebms.ebms_attenuate(xs, idx, d), jnp.ones(64), atol=1e-7
        )

    @settings(max_examples=8, deadline=None)
    @given(
        band=st.sampled_from([16, 256, 1000]),
        n=st.sampled_from([64, 1024, 1536]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, band, n, seed):
        xs = rand(seed, (band,), 0.0, 4.0)
        idx = jax.random.randint(jax.random.PRNGKey(seed + 1), (n,), 0, band)
        d = rand(seed + 2, (n,), 0.0, 1.0)
        np.testing.assert_allclose(
            ebms.ebms_attenuate(xs, idx, d),
            ref.ebms_attenuate_ref(xs, idx, d),
            rtol=1e-5,
            atol=1e-6,
        )
