#!/usr/bin/env python3
"""Lock-discipline lint for rust/src/mpi/ (the static half of SimSan).

SimSan (rust/src/sim/sanitizer.rs) checks lock ORDER dynamically, but it can
only see acquisitions that carry a LockClass. This lint closes the gap
statically by rejecting, in every .rs file under rust/src/mpi/:

  1. raw `std::sync::Mutex` / `std::sync::RwLock` — host locks in mpi/ must
     go through `instrument::HostMutex`, whose acquisition takes a LockClass
     and participates in SimSan's held-lock stack (so holding one across a
     scheduler park is caught);
  2. unclassed acquisitions — bare `.lock()` / `.try_lock()` call sites,
     which SimSan would track only under the anonymous (unordered) tag.
     Sanctioned spellings: `.lock_class(..)`, `.lock_ordinal(..)`,
     `.lock_uncounted(..)`, `.try_lock_class(..)`, and
     `HostMutex::lock(LockClass::..)`;
  3. raw VCI state-cell dereferences (`...0.get()` on the UnsafeCell) —
     the lock-free stream fast path is a *sanctioned hole* in rules 1-2,
     so every raw access must sit either in one of the locked entry
     points (`with_state` / `try_with_state`, serialized by the Guard
     contract) or in a function explicitly audited with a
     `lint:allow-stream-cell` marker comment directly above its `fn`
     (today: `Vci::with_state_stream`, the single-writer entry whose
     safety rests on stream ownership + the SimSan tripwire). A raw
     access anywhere else is exactly a stream path dodging the lint.

A line ending in a `lint:allow-host-mutex` comment is exempt from rules
1-2 — used exactly once, inside `instrument::HostMutex` itself (the
sanctioned wrapper has to contain the raw mutex it wraps).

Exit status: 0 clean, 1 violations (printed as file:line: message).
"""

import re
import sys
from pathlib import Path

ALLOW_MARKER = "lint:allow-host-mutex"
ALLOW_STREAM_MARKER = "lint:allow-stream-cell"

# Rule 3: locked state entries whose serialization comes from the Guard
# contract rather than an audit marker.
LOCKED_STATE_FNS = {"with_state", "try_with_state"}

# Rule 1: raw host lock types. \b keeps std::sync::MutexGuard (in type
# positions of the sanctioned wrapper) from matching.
RAW_HOST_LOCK = re.compile(r"\bstd::sync::(Mutex|RwLock)\b|\buse\s+std::sync::.*\b(Mutex|RwLock)\b")

# Rule 2: an acquisition with no LockClass argument. `.lock(LockClass::..)`
# (HostMutex) does not match because of the empty-parens requirement;
# `.lock_class(` / `.lock_ordinal(` / `.lock_uncounted(` /
# `.try_lock_class(` do not match because of the word boundary after "lock".
BARE_ACQUIRE = re.compile(r"\.(lock|try_lock)\(\s*\)")

# Rule 3: a raw dereference of the newtyped UnsafeCell holding VCI state
# (`self.state.0.get()` and any alias thereof).
RAW_STATE_CELL = re.compile(r"\.0\s*\.\s*get\(\s*\)")

# A `fn` item declaration (case-sensitive, so `FnOnce(..)` in closure
# bounds never matches).
FN_DECL = re.compile(r"\bfn\s+(\w+)")


def strip_strings(line: str) -> str:
    """Blank out string literals so quoted examples never trip the rules."""
    return re.sub(r'"(?:[^"\\]|\\.)*"', '""', line)


def lint_file(path: Path) -> list[str]:
    errors = []
    # Rule-3 state: a `lint:allow-stream-cell` marker audits the NEXT `fn`
    # item; the exemption covers that function's body (until the next fn).
    pending_stream_marker = False
    fn_stream_exempt = False
    for lineno, raw in enumerate(path.read_text().splitlines(), start=1):
        if ALLOW_STREAM_MARKER in raw:
            pending_stream_marker = True
            continue
        if ALLOW_MARKER in raw:
            continue
        # Drop line comments (incl. doc comments) before matching: prose is
        # allowed to *name* std::sync::Mutex.
        code = strip_strings(raw).split("//", 1)[0]
        decl = FN_DECL.search(code)
        if decl:
            fn_stream_exempt = pending_stream_marker or decl.group(1) in LOCKED_STATE_FNS
            pending_stream_marker = False
        if RAW_STATE_CELL.search(code) and not fn_stream_exempt:
            errors.append(
                f"{path}:{lineno}: raw VCI state-cell access outside the "
                f"locked entries — route through with_state()/"
                f"try_with_state()/with_state_stream(), or audit the "
                f"enclosing fn with a `// {ALLOW_STREAM_MARKER}` marker "
                f"directly above it"
            )
        if RAW_HOST_LOCK.search(code):
            errors.append(
                f"{path}:{lineno}: raw std::sync lock in mpi/ — use "
                f"instrument::HostMutex and pass a LockClass (or mark the "
                f"line `// {ALLOW_MARKER}` if it IS the wrapper)"
            )
        if BARE_ACQUIRE.search(code):
            errors.append(
                f"{path}:{lineno}: unclassed lock acquisition — pass a "
                f"LockClass via .lock_class()/.lock_ordinal()/"
                f".lock_uncounted()/.try_lock_class() (or .lock(LockClass::..) "
                f"on a HostMutex) so SimSan can order-check it"
            )
    return errors


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path("rust/src/mpi")
    if not root.is_dir():
        print(f"lint_lock_discipline: no such directory: {root}", file=sys.stderr)
        return 2
    files = sorted(root.rglob("*.rs"))
    if not files:
        print(f"lint_lock_discipline: no .rs files under {root}", file=sys.stderr)
        return 2
    errors = [e for f in files for e in lint_file(f)]
    for e in errors:
        print(e)
    print(
        f"lint_lock_discipline: {len(files)} files, "
        f"{len(errors)} violation(s)",
        file=sys.stderr,
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
