#!/usr/bin/env python3
"""Lock-discipline lint for rust/src/mpi/ (the static half of SimSan).

SimSan (rust/src/sim/sanitizer.rs) checks lock ORDER dynamically, but it can
only see acquisitions that carry a LockClass. This lint closes the gap
statically by rejecting, in every .rs file under rust/src/mpi/:

  1. raw `std::sync::Mutex` / `std::sync::RwLock` — host locks in mpi/ must
     go through `instrument::HostMutex`, whose acquisition takes a LockClass
     and participates in SimSan's held-lock stack (so holding one across a
     scheduler park is caught);
  2. unclassed acquisitions — bare `.lock()` / `.try_lock()` call sites,
     which SimSan would track only under the anonymous (unordered) tag.
     Sanctioned spellings: `.lock_class(..)`, `.lock_ordinal(..)`,
     `.lock_uncounted(..)`, `.try_lock_class(..)`, and
     `HostMutex::lock(LockClass::..)`.

A line ending in a `lint:allow-host-mutex` comment is exempt from both
rules — used exactly once, inside `instrument::HostMutex` itself (the
sanctioned wrapper has to contain the raw mutex it wraps).

Exit status: 0 clean, 1 violations (printed as file:line: message).
"""

import re
import sys
from pathlib import Path

ALLOW_MARKER = "lint:allow-host-mutex"

# Rule 1: raw host lock types. \b keeps std::sync::MutexGuard (in type
# positions of the sanctioned wrapper) from matching.
RAW_HOST_LOCK = re.compile(r"\bstd::sync::(Mutex|RwLock)\b|\buse\s+std::sync::.*\b(Mutex|RwLock)\b")

# Rule 2: an acquisition with no LockClass argument. `.lock(LockClass::..)`
# (HostMutex) does not match because of the empty-parens requirement;
# `.lock_class(` / `.lock_ordinal(` / `.lock_uncounted(` /
# `.try_lock_class(` do not match because of the word boundary after "lock".
BARE_ACQUIRE = re.compile(r"\.(lock|try_lock)\(\s*\)")


def strip_strings(line: str) -> str:
    """Blank out string literals so quoted examples never trip the rules."""
    return re.sub(r'"(?:[^"\\]|\\.)*"', '""', line)


def lint_file(path: Path) -> list[str]:
    errors = []
    for lineno, raw in enumerate(path.read_text().splitlines(), start=1):
        if ALLOW_MARKER in raw:
            continue
        # Drop line comments (incl. doc comments) before matching: prose is
        # allowed to *name* std::sync::Mutex.
        code = strip_strings(raw).split("//", 1)[0]
        if RAW_HOST_LOCK.search(code):
            errors.append(
                f"{path}:{lineno}: raw std::sync lock in mpi/ — use "
                f"instrument::HostMutex and pass a LockClass (or mark the "
                f"line `// {ALLOW_MARKER}` if it IS the wrapper)"
            )
        if BARE_ACQUIRE.search(code):
            errors.append(
                f"{path}:{lineno}: unclassed lock acquisition — pass a "
                f"LockClass via .lock_class()/.lock_ordinal()/"
                f".lock_uncounted()/.try_lock_class() (or .lock(LockClass::..) "
                f"on a HostMutex) so SimSan can order-check it"
            )
    return errors


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path("rust/src/mpi")
    if not root.is_dir():
        print(f"lint_lock_discipline: no such directory: {root}", file=sys.stderr)
        return 2
    files = sorted(root.rglob("*.rs"))
    if not files:
        print(f"lint_lock_discipline: no .rs files under {root}", file=sys.stderr)
        return 2
    errors = [e for f in files for e in lint_file(f)]
    for e in errors:
        print(e)
    print(
        f"lint_lock_discipline: {len(files)} files, "
        f"{len(errors)} violation(s)",
        file=sys.stderr,
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
