#!/usr/bin/env python3
"""Doc-integrity lint for the markdown guides (docs/ + README.md).

Prose drifts: a renamed module silently breaks the architecture guide's
links, and a renamed bench gate silently orphans the info-key table's
"proven by" column. This lint makes both failures loud:

  1. every relative markdown link `[text](path)` in README.md and
     docs/**/*.md must resolve to an existing file or directory
     (anchors `#...` are stripped; absolute URLs `http(s)://` and
     pure-anchor links are skipped);
  2. every `[[bench gate: NAME]]` marker in docs/**/*.md must name a
     gate that literally appears in some rust/benches/*.rs source —
     the same names the bench JSON reports emit and CI's bench job
     gates on.

Exit status: 0 clean, 1 violations (printed as file:line: message),
2 usage/setup error. Optional argv[1] overrides the repo root.
"""

import re
import sys
from pathlib import Path

# [text](target) — target captured up to the closing paren. Images
# (![alt](..)) match too, which is what we want.
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
GATE_MARKER = re.compile(r"\[\[bench gate:\s*([A-Za-z0-9_]+)\s*\]\]")


def check_links(md: Path, root: Path) -> list[str]:
    errors = []
    for lineno, line in enumerate(md.read_text().splitlines(), start=1):
        for target in MD_LINK.findall(line):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(
                    f"{md.relative_to(root)}:{lineno}: broken link "
                    f"{target!r} (resolved to {resolved})"
                )
    return errors


def check_gates(md: Path, root: Path, bench_text: str) -> list[str]:
    errors = []
    for lineno, line in enumerate(md.read_text().splitlines(), start=1):
        for gate in GATE_MARKER.findall(line):
            if gate not in bench_text:
                errors.append(
                    f"{md.relative_to(root)}:{lineno}: [[bench gate: "
                    f"{gate}]] names no gate in rust/benches/*.rs — "
                    f"renamed or removed?"
                )
    return errors


def main() -> int:
    root = (Path(sys.argv[1]) if len(sys.argv) > 1 else Path(".")).resolve()
    docs = sorted((root / "docs").rglob("*.md")) if (root / "docs").is_dir() else []
    readme = root / "README.md"
    targets = ([readme] if readme.is_file() else []) + docs
    if not targets:
        print(f"lint_doc_links: no README.md or docs/*.md under {root}", file=sys.stderr)
        return 2
    benches = sorted((root / "rust" / "benches").glob("*.rs"))
    if not benches:
        print(f"lint_doc_links: no bench sources under {root}/rust/benches", file=sys.stderr)
        return 2
    bench_text = "\n".join(b.read_text() for b in benches)
    errors = []
    for md in targets:
        errors += check_links(md, root)
        errors += check_gates(md, root, bench_text)
    for e in errors:
        print(e)
    print(
        f"lint_doc_links: {len(targets)} markdown file(s), "
        f"{len(errors)} violation(s)",
        file=sys.stderr,
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
